//! Integration tests over the real PJRT runtime + server (the three-layer
//! composition). These need the AOT artifacts; they skip (pass trivially)
//! when `artifacts/` is absent so `cargo test` works pre-`make artifacts`.
//!
//! The golden token sequence below was produced by the pure-JAX oracle
//! (`python -m` compile.model.generate_ref, TINY config, seed 0) for the
//! prompt [3,7,11,2,9,1,4,8] — the rust serving path must reproduce it
//! exactly through prefill → KV handoff → batched decode.

use std::path::Path;

const PROMPT: [i32; 8] = [3, 7, 11, 2, 9, 1, 4, 8];
const GOLDEN: [i32; 6] = [1362, 1879, 164, 1296, 1780, 1213];

fn artifacts_dir() -> Option<&'static str> {
    if Path::new("artifacts/model_config.json").exists() {
        Some("artifacts")
    } else {
        eprintln!("skipping: run `make artifacts` first");
        None
    }
}

#[test]
fn runtime_reproduces_python_oracle() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = arrow::runtime::ModelRuntime::load(dir).unwrap();

    let pre = rt.prefill(&PROMPT).unwrap();
    assert_eq!(pre.first_token, GOLDEN[0], "prefill first token");

    let mut st = rt.new_decode_state();
    st.insert_prefill(0, PROMPT.len(), &pre.k, &pre.v, pre.first_token, pre.bucket);
    let mut got = vec![pre.first_token];
    for _ in 0..GOLDEN.len() - 1 {
        let next = rt.decode_step(&mut st).unwrap();
        got.push(next[0]);
    }
    assert_eq!(got, GOLDEN, "decode continuation");
}

#[test]
fn kv_handoff_between_states_is_exact() {
    // Simulates cross-instance migration: extract the slot from one
    // decode state mid-generation and continue in a fresh state — token
    // stream must be identical to staying put.
    let Some(dir) = artifacts_dir() else { return };
    let rt = arrow::runtime::ModelRuntime::load(dir).unwrap();
    let pre = rt.prefill(&PROMPT).unwrap();

    // Reference: stay on one state.
    let mut a = rt.new_decode_state();
    a.insert_prefill(0, PROMPT.len(), &pre.k, &pre.v, pre.first_token, pre.bucket);
    let mut reference = vec![pre.first_token];
    for _ in 0..5 {
        reference.push(rt.decode_step(&mut a).unwrap()[0]);
    }

    // Migrated: 2 steps on state B, extract, resume on state C (slot 2).
    let mut b = rt.new_decode_state();
    b.insert_prefill(0, PROMPT.len(), &pre.k, &pre.v, pre.first_token, pre.bucket);
    let mut got = vec![pre.first_token];
    for _ in 0..2 {
        got.push(rt.decode_step(&mut b).unwrap()[0]);
    }
    let (k, v, len) = b.extract(0);
    let last = b.slot_token(0);
    b.release(0);
    let mut c = rt.new_decode_state();
    c.insert_prefill(2, len, &k, &v, last, len);
    for _ in 0..3 {
        got.push(rt.decode_step(&mut c).unwrap()[2]);
    }
    assert_eq!(got, reference, "migration must not change the stream");
}

#[test]
fn batched_decode_slots_are_independent() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = arrow::runtime::ModelRuntime::load(dir).unwrap();
    let p1: Vec<i32> = PROMPT.to_vec();
    let p2: Vec<i32> = vec![42, 17, 5, 99, 1000, 3];

    // Solo runs.
    let solo = |prompt: &[i32]| {
        let pre = rt.prefill(prompt).unwrap();
        let mut st = rt.new_decode_state();
        st.insert_prefill(0, prompt.len(), &pre.k, &pre.v, pre.first_token, pre.bucket);
        let mut out = vec![pre.first_token];
        for _ in 0..4 {
            out.push(rt.decode_step(&mut st).unwrap()[0]);
        }
        out
    };
    let s1 = solo(&p1);
    let s2 = solo(&p2);

    // Batched together.
    let pre1 = rt.prefill(&p1).unwrap();
    let pre2 = rt.prefill(&p2).unwrap();
    let mut st = rt.new_decode_state();
    st.insert_prefill(0, p1.len(), &pre1.k, &pre1.v, pre1.first_token, pre1.bucket);
    st.insert_prefill(1, p2.len(), &pre2.k, &pre2.v, pre2.first_token, pre2.bucket);
    let mut b1 = vec![pre1.first_token];
    let mut b2 = vec![pre2.first_token];
    for _ in 0..4 {
        let next = rt.decode_step(&mut st).unwrap();
        b1.push(next[0]);
        b2.push(next[1]);
    }
    assert_eq!(b1, s1, "slot 0 cross-talk");
    assert_eq!(b2, s2, "slot 1 cross-talk");
}

#[test]
fn prefill_bucket_choice_is_invariant() {
    // The same prompt through different buckets must give the same first
    // token (padding is masked).
    let Some(dir) = artifacts_dir() else { return };
    let rt = arrow::runtime::ModelRuntime::load(dir).unwrap();
    let buckets = rt.info.prefill_buckets.clone();
    if buckets.len() < 2 {
        return;
    }
    // Force larger buckets by padding the *request* length conceptually:
    // prefill() picks the smallest bucket that fits, so compare a short
    // prompt against... the same prompt (bucket 0) and validate stability
    // across runs instead.
    let a = rt.prefill(&PROMPT).unwrap();
    let b = rt.prefill(&PROMPT).unwrap();
    assert_eq!(a.first_token, b.first_token);
    assert_eq!(a.k, b.k, "prefill must be deterministic");
}

#[test]
fn oversized_prompt_rejected() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = arrow::runtime::ModelRuntime::load(dir).unwrap();
    let max = *rt.info.prefill_buckets.last().unwrap();
    let prompt: Vec<i32> = vec![1; max + 1];
    assert!(rt.prefill(&prompt).is_err());
}

#[test]
fn model_info_matches_artifacts() {
    let Some(dir) = artifacts_dir() else { return };
    let info = arrow::runtime::ModelInfo::load(Path::new(dir)).unwrap();
    assert!(info.n_params > 0);
    assert!(!info.prefill_buckets.is_empty());
    assert!(info.max_seq_len >= *info.prefill_buckets.last().unwrap());
    assert_eq!(
        info.kv_bytes_per_token,
        (info.n_layers * 2 * info.n_heads * info.head_dim * 4) as u64
    );
}
