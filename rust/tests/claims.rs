//! Paper-claims conformance tier (PR 5).
//!
//! Asserts the paper's qualitative cross-system orderings under the
//! dimensionless [`CostModel::normalized`] preset, so the claims are
//! deterministic properties of the *scheduler* and survive any future
//! hardware recalibration of `h800_llama8b`.
//!
//! Budget: the default run uses the smoke grid (the full grid is the
//! `arrow claims` CLI's job); `ARROW_CLAIMS_FULL=1` opts a test run into
//! the full grid. The headline burst assertion always runs on the 300s
//! azure_code clip regardless — shorter clips can miss the burst minutes
//! entirely (seed-test triage note, PR 3).

use arrow::harness::{self, ClaimsConfig, STATIC_SPLITS};
use arrow::scenarios::System;
use arrow::trace::catalog;

fn env_truthy(key: &str) -> bool {
    std::env::var(key).map_or(false, |v| v != "0" && !v.is_empty())
}

/// Smoke grid by default; `ARROW_CLAIMS_FULL=1` escalates, and the ci.sh
/// `ARROW_CLAIMS_SMOKE=1` knob caps it back down explicitly.
///
/// Debug builds additionally thin the sweep: the PR-4 moment oracles
/// (`debug_assert` queue walks on every placement) make unoptimized sims
/// an order of magnitude slower, and ci.sh runs this suite under both
/// profiles *plus* the release `arrow claims` gate — the full-strength
/// runs are the release ones; the debug pass checks the same claims at
/// reduced resolution.
fn test_cfg() -> ClaimsConfig {
    let mut cfg = if env_truthy("ARROW_CLAIMS_FULL") && !harness::smoke_env() {
        ClaimsConfig::full()
    } else {
        ClaimsConfig::smoke()
    };
    if cfg!(debug_assertions) {
        cfg.clip_seconds = cfg.clip_seconds.min(60.0);
        cfg.rate_search_tolerance = cfg.rate_search_tolerance.max(0.3);
    }
    cfg
}

/// Bisection resolution for the 300s-clip tests below: strict in
/// release, looser in debug (same wall-clock rationale as `test_cfg`).
fn search_tolerance() -> f64 {
    if cfg!(debug_assertions) {
        0.25
    } else {
        0.1
    }
}

#[test]
fn claims_report_covers_all_eight_systems_on_all_table1_workloads() {
    // Coverage is the contract: the report must measure every system —
    // the paper's six plus the PR-10 adversaries — on every Table-1
    // workload, account every request, and serialize.
    let cfg = ClaimsConfig {
        rate_mults: vec![2.0],
        clip_seconds: 30.0,
        rate_search_tolerance: 0.5,
        ..ClaimsConfig::smoke()
    };
    let report = harness::run_claims(&cfg);
    assert_eq!(report.outcomes.len(), catalog::table1().len());
    for o in &report.outcomes {
        assert_eq!(o.systems.len(), System::all().len(), "{}", o.workload);
        assert!(o.n_requests > 0, "{}: empty clip", o.workload);
        for sys in &o.systems {
            for p in &sys.sweep {
                assert_eq!(
                    p.report.n_finished + p.report.n_failed,
                    p.report.n_requests,
                    "{}/{}: accounting",
                    o.workload,
                    sys.system.label()
                );
            }
            assert!(sys.max_sustainable.is_finite());
        }
    }
    let parsed = arrow::json::Json::parse(&report.to_json().encode())
        .expect("claims report must be machine-readable JSON");
    assert_eq!(
        parsed.get("workloads").as_arr().unwrap().len(),
        catalog::table1().len()
    );
}

#[test]
fn arrow_at_least_matches_every_static_split_on_goodput_under_burst() {
    // The acceptance headline: under the bursty azure_code workload at
    // the stress point (lightest swept overload of the best static
    // split), Arrow's goodput is at least every static split's, under
    // the normalized cost model. 300s clip: long enough to include burst
    // minutes (shorter clips of this trace can be burst-free and make
    // the comparison vacuous).
    let w = catalog::by_name("azure_code").unwrap();
    let cfg = ClaimsConfig {
        clip_seconds: 300.0,
        rate_mults: vec![4.0, 8.0, 12.0, 16.0, 24.0],
        rate_search_tolerance: search_tolerance(),
        ..ClaimsConfig::smoke()
    };
    let report = harness::run_claims_for(&[w], &cfg);
    let o = &report.outcomes[0];
    let m = o.stress_mult;
    let arrow = o.system(System::Arrow).at_mult(m);
    for &s in &STATIC_SPLITS {
        let st = o.system(s).at_mult(m);
        assert!(
            arrow.goodput_tokens >= st.goodput_tokens * (1.0 - cfg.tolerance),
            "arrow goodput {:.1} tok/s below {} {:.1} at stress x{m}",
            arrow.goodput_tokens,
            s.label(),
            st.goodput_tokens
        );
        assert!(
            arrow.slo_attainment >= st.slo_attainment - 0.02,
            "arrow attainment {:.3} below {} {:.3} at stress x{m}",
            arrow.slo_attainment,
            s.label(),
            st.slo_attainment
        );
    }
    // And the max-rate orderings the verdicts computed on the same run.
    for v in report.verdicts.iter().filter(|v| v.claim.starts_with("max_rate:")) {
        assert!(v.holds, "{} failed: {}", v.claim, v.detail);
    }
    // PR 10: at the stress point of this same burst run, deflection must
    // pay for itself — goodput at least Arrow's minus the tolerance band
    // (small prefills complete inside the window a flip would spend
    // draining). The harness computes the verdict; this tier pins it on
    // the headline workload.
    let fw = report
        .verdicts
        .iter()
        .find(|v| v.claim == "deflect:flip_window:goodput>=arrow")
        .expect("flip-window verdict must be emitted for azure_code");
    assert!(fw.holds, "{} failed: {}", fw.claim, fw.detail);
    for claim in ["deflect:max_rate>=arrow", "unified:max_rate:arrow>=unified"] {
        assert!(
            report.verdicts.iter().any(|v| v.claim == claim),
            "adversary verdict {claim} missing from the burst report"
        );
    }
}

#[test]
fn disaggregated_tpot_stable_while_colocated_ttft_degrades() {
    // §7.2's shape claims on the burst workload: the colocated engine's
    // P90 TTFT inflates under load while its decode-prioritized TPOT
    // stays inside the SLO — and Arrow's disaggregated TPOT stays inside
    // the SLO even past saturation.
    let w = catalog::by_name("azure_code").unwrap();
    let tpot_slo = w.tpot_slo;
    let cfg = ClaimsConfig {
        clip_seconds: 300.0,
        rate_mults: vec![2.0, 40.0],
        rate_search_tolerance: 0.5, // max rates unused by this test
        ..ClaimsConfig::smoke()
    };
    let report = harness::run_claims_for(&[w], &cfg);
    let o = &report.outcomes[0];
    let coloc = o.system(System::VllmColocated);
    let (low, high) = (coloc.at_mult(2.0), coloc.at_mult(40.0));
    assert!(
        high.p90_ttft > 3.0 * low.p90_ttft,
        "colocated TTFT must inflate under saturation: {:.3}s -> {:.3}s",
        low.p90_ttft,
        high.p90_ttft
    );
    assert!(
        high.p90_tpot <= tpot_slo,
        "colocated decode priority must keep TPOT inside the SLO: {:.4}s > {}s",
        high.p90_tpot,
        tpot_slo
    );
    let arrow_high = o.system(System::Arrow).at_mult(40.0);
    assert!(
        arrow_high.p90_tpot <= tpot_slo,
        "arrow's disaggregated TPOT must stay inside the SLO past saturation: {:.4}s > {}s",
        arrow_high.p90_tpot,
        tpot_slo
    );
}

#[test]
fn all_claims_hold_on_the_configured_grid() {
    // The whole verdict set — max-rate orderings, stress-point goodput
    // orderings, and the degradation shapes — across every Table-1
    // workload on the smoke grid (full grid with ARROW_CLAIMS_FULL=1).
    let report = harness::run_claims(&test_cfg());
    let failed = report.failed();
    assert!(
        failed.is_empty(),
        "{} paper claim(s) failed:\n{}",
        failed.len(),
        failed
            .iter()
            .map(|v| format!("  [{}] {} — {}", v.workload, v.claim, v.detail))
            .collect::<Vec<_>>()
            .join("\n")
    );
}
