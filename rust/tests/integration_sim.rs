//! Integration tests over the full simulation stack: trace generation →
//! scenario construction → event loop → metrics, across all six systems.

use arrow::costmodel::CostModel;
use arrow::metrics::SloReport;
use arrow::request::RequestState;
use arrow::scenarios::{build, System};
use arrow::trace::catalog;

fn run_clip_cost(
    sys: System,
    workload: &str,
    rate_mult: f64,
    seed: u64,
    clip: f64,
    cost: &CostModel,
) -> (SloReport, arrow::sim::SimResult, arrow::trace::Trace) {
    let w = catalog::by_name(workload).unwrap();
    let trace = w.generate(seed).clip_seconds(clip);
    let t = trace.with_rate(trace.rate() * rate_mult);
    let cl = build(sys, 8, cost, w.ttft_slo, w.tpot_slo, false);
    let res = cl.run(&t);
    let rep = SloReport::from_records(&res.records, w.ttft_slo, w.tpot_slo, t.duration());
    (rep, res, t)
}

fn run_clip(sys: System, workload: &str, rate_mult: f64, seed: u64, clip: f64) -> (SloReport, arrow::sim::SimResult, arrow::trace::Trace) {
    run_clip_cost(sys, workload, rate_mult, seed, clip, &CostModel::h800_llama8b())
}

fn run(sys: System, workload: &str, rate_mult: f64, seed: u64) -> (SloReport, arrow::sim::SimResult, arrow::trace::Trace) {
    run_clip(sys, workload, rate_mult, seed, 120.0)
}

#[test]
fn every_system_full_accounting_on_every_workload() {
    for sys in System::all() {
        for wname in ["azure_code", "azure_conv", "burstgpt"] {
            let (rep, res, t) = run(sys, wname, 2.0, 3);
            assert_eq!(rep.n_requests, t.len(), "{}/{}", sys.label(), wname);
            assert_eq!(
                rep.n_finished + rep.n_failed,
                rep.n_requests,
                "{}/{}: every request must finish or fail",
                sys.label(),
                wname
            );
            // Token conservation: finished requests produced exactly
            // output_len tokens.
            for (rec, req) in res.records.iter().zip(&t.requests) {
                if rec.finished() {
                    assert_eq!(
                        rec.token_times.len(),
                        req.output_len as usize,
                        "{}/{}: token count",
                        sys.label(),
                        wname
                    );
                }
            }
        }
    }
}

#[test]
fn ttft_tpot_causality() {
    // TTFT >= pure prefill time; token times strictly ordered; first
    // token not before arrival.
    let (_, res, t) = run(System::Arrow, "azure_code", 4.0, 5);
    let cost = CostModel::h800_llama8b();
    for (rec, req) in res.records.iter().zip(&t.requests) {
        if !rec.finished() {
            continue;
        }
        let ttft = rec.ttft().unwrap();
        assert!(ttft > 0.0, "ttft must be positive");
        // Lower bound: compute-only prefill time at full chunk size minus
        // slack for the chunked overhead model.
        let floor = cost.prefill_per_token * req.input_len as f64 * 0.5;
        assert!(
            ttft + 1e-9 >= floor,
            "ttft {ttft} below physical floor {floor} for len {}",
            req.input_len
        );
        assert!(rec.token_times[0] >= req.arrival);
        for w in rec.token_times.windows(2) {
            assert!(w[1] >= w[0] - 1e-9);
        }
    }
}

#[test]
fn arrow_beats_static_baselines_under_burst_load() {
    // The paper's core claim, at reproduction scale, un-quarantined
    // (PR 5): under the dimensionless `CostModel::normalized` preset the
    // cross-system margins are properties of the *scheduler*, so this
    // runs deterministically on every commit with no calibration step.
    //
    // The comparison point is chosen adaptively — the first swept
    // multiplier at which minimal-load (the strongest static split)
    // misses the 90% target — so the assertion always lands in the
    // overload regime the claim is about, wherever the trace's burst
    // minutes fall. 300s clip: long enough to include burst minutes
    // (shorter clips of this trace can be burst-free and make every
    // system trivially pass).
    let norm = CostModel::normalized();
    let grid = [8.0, 12.0, 16.0, 24.0];
    let at = |sys: System, mult: f64| run_clip_cost(sys, "azure_code", mult, 42, 300.0, &norm).0;
    // Walk the grid once, keeping the minimal-load report of the stress
    // point (no re-run of the sim the search just evaluated).
    let mut stress = *grid.last().unwrap();
    let mut ml = None;
    for &m in &grid {
        let r = at(System::MinimalLoad, m);
        let overloaded = r.slo_attainment < 0.9;
        ml = Some(r);
        if overloaded {
            stress = m;
            break;
        }
    }
    let ml = ml.unwrap();
    let arrow = at(System::Arrow, stress);
    let rr = at(System::RoundRobin, stress);
    let ds = at(System::DistServe, stress);
    for (label, s) in [("minimal-load", &ml), ("round-robin", &rr), ("distserve", &ds)] {
        assert!(
            arrow.goodput_tokens >= s.goodput_tokens * 0.95,
            "arrow goodput {:.1} below {label} {:.1} at stress x{stress}",
            arrow.goodput_tokens,
            s.goodput_tokens
        );
        assert!(
            arrow.slo_attainment >= s.slo_attainment - 0.02,
            "arrow attainment {:.3} below {label} {:.3} at stress x{stress}",
            arrow.slo_attainment,
            s.slo_attainment
        );
    }
    // DistServe's unmaintained engine (0.55x efficiency, small KV pool)
    // is strictly dominated in the overload regime.
    assert!(
        arrow.slo_attainment > ds.slo_attainment + 0.05,
        "arrow {} vs distserve {} at stress x{stress}",
        arrow.slo_attainment,
        ds.slo_attainment
    );
}

#[test]
#[ignore = "hardware-calibrated variant: the +0.1 attainment gaps assume the \
            h800_llama8b cost model matches real hardware; run after `arrow \
            calibrate` on the testbed (the normalized variant above is the \
            always-on claim). Run explicitly: cargo test -- --ignored"]
fn arrow_beats_static_baselines_under_burst_load_h800() {
    let mult = 12.0;
    let (arrow, ..) = run_clip(System::Arrow, "azure_code", mult, 42, 300.0);
    let (ml, ..) = run_clip(System::MinimalLoad, "azure_code", mult, 42, 300.0);
    let (rr, ..) = run_clip(System::RoundRobin, "azure_code", mult, 42, 300.0);
    let (ds, ..) = run_clip(System::DistServe, "azure_code", mult, 42, 300.0);
    assert!(
        arrow.slo_attainment > ml.slo_attainment + 0.1,
        "arrow {} vs minimal-load {}",
        arrow.slo_attainment,
        ml.slo_attainment
    );
    assert!(arrow.slo_attainment > rr.slo_attainment + 0.1);
    assert!(arrow.slo_attainment > ds.slo_attainment + 0.1);
}

#[test]
fn arrow_flips_instances_under_load_but_not_at_idle() {
    let (_, busy, _) = run_clip(System::Arrow, "azure_code", 16.0, 42, 300.0);
    assert!(busy.total_flips > 0, "bursty overload must trigger flips");
    let (rep, idle, _) = run(System::Arrow, "azure_code", 0.2, 2);
    assert!(rep.slo_attainment > 0.95, "idle load must be easy");
    // At near-idle load only the occasional borderline-SLO long prompt
    // triggers a flip; the scheduler must not thrash.
    assert!(idle.total_flips < 20, "idle thrashing: {}", idle.total_flips);
}

#[test]
fn vllm_ttft_rises_but_tpot_stays_low_under_load() {
    // §7.2's observation about decode-prioritized colocated serving,
    // un-quarantined (PR 5) under the normalized cost model. The high
    // multiplier (40x) puts the TP=8 colocated engine past *sustained*
    // prefill saturation — TTFT inflation no longer depends on where the
    // trace's burst minutes fall — while decode priority must still hold
    // P90 TPOT inside the 0.1s SLO.
    let norm = CostModel::normalized();
    let (low, ..) = run_clip_cost(System::VllmColocated, "azure_code", 2.0, 4, 300.0, &norm);
    let (high, ..) = run_clip_cost(System::VllmColocated, "azure_code", 40.0, 4, 300.0, &norm);
    assert!(
        high.p90_ttft > 3.0 * low.p90_ttft,
        "TTFT must inflate: {} -> {}",
        low.p90_ttft,
        high.p90_ttft
    );
    assert!(
        high.p90_tpot < 0.1,
        "decode priority keeps TPOT low, got {}",
        high.p90_tpot
    );
    assert!(
        low.p90_tpot < 0.1,
        "TPOT must be inside the SLO at light load too, got {}",
        low.p90_tpot
    );
}

#[test]
#[ignore = "hardware-calibrated variant: the 3x TTFT-inflation ratio at 24x \
            depends on the h800_llama8b chunked-prefill cost shape; run after \
            `arrow calibrate` on the testbed (the normalized variant above is \
            the always-on claim). Run explicitly: cargo test -- --ignored"]
fn vllm_ttft_rises_but_tpot_stays_low_under_load_h800() {
    let (low, ..) = run_clip(System::VllmColocated, "azure_code", 2.0, 4, 300.0);
    let (high, ..) = run_clip(System::VllmColocated, "azure_code", 24.0, 4, 300.0);
    assert!(
        high.p90_ttft > 3.0 * low.p90_ttft,
        "TTFT must inflate: {} -> {}",
        low.p90_ttft,
        high.p90_ttft
    );
    assert!(
        high.p90_tpot < 0.1,
        "decode priority keeps TPOT low, got {}",
        high.p90_tpot
    );
}

#[test]
fn distserve_fails_long_context() {
    // Mooncake's extreme prompts exceed DistServe's usable KV (§7.2:
    // "DistServe triggers OOM errors when processing long-context
    // inputs").
    let w = catalog::by_name("mooncake_conv").unwrap();
    let trace = w.generate(1).clip_seconds(120.0);
    let cl = build(System::DistServe, 8, &CostModel::h800_llama8b(), w.ttft_slo, w.tpot_slo, false);
    let res = cl.run(&trace);
    let failed = res
        .records
        .iter()
        .filter(|r| r.state == RequestState::Failed)
        .count();
    assert!(failed > 0, "long-context OOM failures expected");
    // Arrow completes the same clip.
    let cl = build(System::Arrow, 8, &CostModel::h800_llama8b(), w.ttft_slo, w.tpot_slo, false);
    let res = cl.run(&trace);
    let arrow_failed = res
        .records
        .iter()
        .filter(|r| r.state == RequestState::Failed)
        .count();
    assert!(arrow_failed < failed);
}

#[test]
fn runs_are_deterministic_across_threads() {
    // The figure harness runs simulations on worker threads; results must
    // not depend on scheduling.
    use arrow::util::threads::parallel_map;
    let reports = parallel_map(vec![0u32; 4], 4, |_| {
        run(System::Arrow, "burstgpt", 8.0, 9).0
    });
    for r in &reports[1..] {
        assert_eq!(r.n_finished, reports[0].n_finished);
        assert!((r.slo_attainment - reports[0].slo_attainment).abs() < 1e-12);
        assert!((r.p90_ttft - reports[0].p90_ttft).abs() < 1e-12);
    }
}

#[test]
fn rate_scaling_monotonicity() {
    // Higher request rate must not increase SLO attainment (sanity of the
    // whole pipeline; allows tiny noise from burst alignment).
    let mut last = f64::INFINITY;
    for mult in [1.0, 8.0, 32.0] {
        let (rep, ..) = run_clip(System::MinimalLoad, "azure_code", mult, 6, 300.0);
        assert!(
            rep.slo_attainment <= last + 0.05,
            "attainment should not rise with load: {} -> {}",
            last,
            rep.slo_attainment
        );
        last = rep.slo_attainment;
    }
}
