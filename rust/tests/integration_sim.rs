//! Integration tests over the full simulation stack: trace generation →
//! scenario construction → event loop → metrics, across all six systems.

use arrow::costmodel::CostModel;
use arrow::metrics::SloReport;
use arrow::request::RequestState;
use arrow::scenarios::{build, System};
use arrow::trace::catalog;

fn run_clip(sys: System, workload: &str, rate_mult: f64, seed: u64, clip: f64) -> (SloReport, arrow::sim::SimResult, arrow::trace::Trace) {
    let w = catalog::by_name(workload).unwrap();
    let trace = w.generate(seed).clip_seconds(clip);
    let t = trace.with_rate(trace.rate() * rate_mult);
    let cl = build(sys, 8, &CostModel::h800_llama8b(), w.ttft_slo, w.tpot_slo, false);
    let res = cl.run(&t);
    let rep = SloReport::from_records(&res.records, w.ttft_slo, w.tpot_slo, t.duration());
    (rep, res, t)
}

fn run(sys: System, workload: &str, rate_mult: f64, seed: u64) -> (SloReport, arrow::sim::SimResult, arrow::trace::Trace) {
    run_clip(sys, workload, rate_mult, seed, 120.0)
}

#[test]
fn every_system_full_accounting_on_every_workload() {
    for sys in System::all() {
        for wname in ["azure_code", "azure_conv", "burstgpt"] {
            let (rep, res, t) = run(sys, wname, 2.0, 3);
            assert_eq!(rep.n_requests, t.len(), "{}/{}", sys.label(), wname);
            assert_eq!(
                rep.n_finished + rep.n_failed,
                rep.n_requests,
                "{}/{}: every request must finish or fail",
                sys.label(),
                wname
            );
            // Token conservation: finished requests produced exactly
            // output_len tokens.
            for (rec, req) in res.records.iter().zip(&t.requests) {
                if rec.finished() {
                    assert_eq!(
                        rec.token_times.len(),
                        req.output_len as usize,
                        "{}/{}: token count",
                        sys.label(),
                        wname
                    );
                }
            }
        }
    }
}

#[test]
fn ttft_tpot_causality() {
    // TTFT >= pure prefill time; token times strictly ordered; first
    // token not before arrival.
    let (_, res, t) = run(System::Arrow, "azure_code", 4.0, 5);
    let cost = CostModel::h800_llama8b();
    for (rec, req) in res.records.iter().zip(&t.requests) {
        if !rec.finished() {
            continue;
        }
        let ttft = rec.ttft().unwrap();
        assert!(ttft > 0.0, "ttft must be positive");
        // Lower bound: compute-only prefill time at full chunk size minus
        // slack for the chunked overhead model.
        let floor = cost.prefill_per_token * req.input_len as f64 * 0.5;
        assert!(
            ttft + 1e-9 >= floor,
            "ttft {ttft} below physical floor {floor} for len {}",
            req.input_len
        );
        assert!(rec.token_times[0] >= req.arrival);
        for w in rec.token_times.windows(2) {
            assert!(w[1] >= w[0] - 1e-9);
        }
    }
}

#[test]
#[ignore = "uncalibrated cross-system margin (seed-test triage, PR 3): the +0.1 \
            attainment gaps assume the h800_llama8b cost model matches real \
            hardware; un-ignore after the first `arrow calibrate` run on a \
            machine with a toolchain confirms them — tracked in ROADMAP \
            'Open items'. Run explicitly: cargo test -- --ignored"]
fn arrow_beats_static_baselines_under_burst_load() {
    // The paper's core claim, at reproduction scale: under bursty
    // azure_code load past the static splits' saturation point, Arrow's
    // adaptive scheduling sustains strictly higher SLO attainment.
    let mult = 12.0;
    // 300s clip: long enough to include burst minutes (shorter clips of
    // this trace have no burst and every system trivially passes).
    let (arrow, ..) = run_clip(System::Arrow, "azure_code", mult, 42, 300.0);
    let (ml, ..) = run_clip(System::MinimalLoad, "azure_code", mult, 42, 300.0);
    let (rr, ..) = run_clip(System::RoundRobin, "azure_code", mult, 42, 300.0);
    let (ds, ..) = run_clip(System::DistServe, "azure_code", mult, 42, 300.0);
    assert!(
        arrow.slo_attainment > ml.slo_attainment + 0.1,
        "arrow {} vs minimal-load {}",
        arrow.slo_attainment,
        ml.slo_attainment
    );
    assert!(arrow.slo_attainment > rr.slo_attainment + 0.1);
    assert!(arrow.slo_attainment > ds.slo_attainment + 0.1);
}

#[test]
fn arrow_flips_instances_under_load_but_not_at_idle() {
    let (_, busy, _) = run_clip(System::Arrow, "azure_code", 16.0, 42, 300.0);
    assert!(busy.total_flips > 0, "bursty overload must trigger flips");
    let (rep, idle, _) = run(System::Arrow, "azure_code", 0.2, 2);
    assert!(rep.slo_attainment > 0.95, "idle load must be easy");
    // At near-idle load only the occasional borderline-SLO long prompt
    // triggers a flip; the scheduler must not thrash.
    assert!(idle.total_flips < 20, "idle thrashing: {}", idle.total_flips);
}

#[test]
#[ignore = "uncalibrated interference margin (seed-test triage, PR 3): the 3x \
            TTFT-inflation ratio depends on the chunked-prefill cost shape; \
            un-ignore after first real calibration — tracked in ROADMAP 'Open \
            items'. Run explicitly: cargo test -- --ignored"]
fn vllm_ttft_rises_but_tpot_stays_low_under_load() {
    // §7.2's observation about decode-prioritized colocated serving.
    let (low, ..) = run_clip(System::VllmColocated, "azure_code", 2.0, 4, 300.0);
    let (high, ..) = run_clip(System::VllmColocated, "azure_code", 24.0, 4, 300.0);
    assert!(
        high.p90_ttft > 3.0 * low.p90_ttft,
        "TTFT must inflate: {} -> {}",
        low.p90_ttft,
        high.p90_ttft
    );
    assert!(
        high.p90_tpot < 0.1,
        "decode priority keeps TPOT low, got {}",
        high.p90_tpot
    );
}

#[test]
fn distserve_fails_long_context() {
    // Mooncake's extreme prompts exceed DistServe's usable KV (§7.2:
    // "DistServe triggers OOM errors when processing long-context
    // inputs").
    let w = catalog::by_name("mooncake_conv").unwrap();
    let trace = w.generate(1).clip_seconds(120.0);
    let cl = build(System::DistServe, 8, &CostModel::h800_llama8b(), w.ttft_slo, w.tpot_slo, false);
    let res = cl.run(&trace);
    let failed = res
        .records
        .iter()
        .filter(|r| r.state == RequestState::Failed)
        .count();
    assert!(failed > 0, "long-context OOM failures expected");
    // Arrow completes the same clip.
    let cl = build(System::Arrow, 8, &CostModel::h800_llama8b(), w.ttft_slo, w.tpot_slo, false);
    let res = cl.run(&trace);
    let arrow_failed = res
        .records
        .iter()
        .filter(|r| r.state == RequestState::Failed)
        .count();
    assert!(arrow_failed < failed);
}

#[test]
fn runs_are_deterministic_across_threads() {
    // The figure harness runs simulations on worker threads; results must
    // not depend on scheduling.
    use arrow::util::threads::parallel_map;
    let reports = parallel_map(vec![0u32; 4], 4, |_| {
        run(System::Arrow, "burstgpt", 8.0, 9).0
    });
    for r in &reports[1..] {
        assert_eq!(r.n_finished, reports[0].n_finished);
        assert!((r.slo_attainment - reports[0].slo_attainment).abs() < 1e-12);
        assert!((r.p90_ttft - reports[0].p90_ttft).abs() < 1e-12);
    }
}

#[test]
fn rate_scaling_monotonicity() {
    // Higher request rate must not increase SLO attainment (sanity of the
    // whole pipeline; allows tiny noise from burst alignment).
    let mut last = f64::INFINITY;
    for mult in [1.0, 8.0, 32.0] {
        let (rep, ..) = run_clip(System::MinimalLoad, "azure_code", mult, 6, 300.0);
        assert!(
            rep.slo_attainment <= last + 0.05,
            "attainment should not rise with load: {} -> {}",
            last,
            rep.slo_attainment
        );
        last = rep.slo_attainment;
    }
}
