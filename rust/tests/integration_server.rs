//! Integration test over the real-mode HTTP serving path: boots the full
//! server (engines + coordinator + HTTP) on an ephemeral port, issues
//! concurrent requests, checks responses and /metrics. Skips when
//! artifacts are absent.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

use arrow::json::Json;

fn http(addr: &str, raw: String) -> Option<String> {
    let mut s = TcpStream::connect(addr).ok()?;
    s.set_read_timeout(Some(Duration::from_secs(120))).ok();
    s.write_all(raw.as_bytes()).ok()?;
    let mut out = String::new();
    s.read_to_string(&mut out).ok()?;
    out.split_once("\r\n\r\n").map(|x| x.1.to_string())
}

fn post(addr: &str, path: &str, body: &str) -> Option<String> {
    http(
        addr,
        format!(
            "POST {path} HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        ),
    )
}

const ADMIN_TOKEN: &str = "test-admin-token";

fn post_admin(addr: &str, path: &str, body: &str) -> Option<String> {
    http(
        addr,
        format!(
            "POST {path} HTTP/1.1\r\nHost: x\r\nX-Admin-Token: {ADMIN_TOKEN}\r\n\
             Content-Length: {}\r\n\r\n{body}",
            body.len()
        ),
    )
}

fn get(addr: &str, path: &str) -> Option<String> {
    http(addr, format!("GET {path} HTTP/1.1\r\nHost: x\r\n\r\n"))
}

#[test]
fn server_end_to_end() {
    if !std::path::Path::new("artifacts/model_config.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    const PORT: u16 = 18911;
    let addr = format!("127.0.0.1:{PORT}");
    // Flight recorder (PR 9): journal every scheduling decision this test
    // provokes, then replay the journal offline at the end.
    let journal = std::env::temp_dir().join(format!(
        "arrow-integration-journal-{}.arwj",
        std::process::id()
    ));
    let jpath = journal.to_string_lossy().to_string();
    std::thread::spawn(move || {
        arrow::server::serve(arrow::server::ServeConfig {
            artifacts_dir: "artifacts".into(),
            port: PORT,
            instances: 2,
            ttft_slo: 2.0,
            tpot_slo: 0.5,
            admin_token: Some(ADMIN_TOKEN.into()),
            max_inflight: 256,
            request_deadline_s: 120.0,
            journal_path: Some(jpath),
        })
        .unwrap();
    });
    let t0 = Instant::now();
    while get(&addr, "/healthz").as_deref() != Some("ok") {
        assert!(t0.elapsed() < Duration::from_secs(120), "server never ready");
        std::thread::sleep(Duration::from_millis(250));
    }

    // Concurrent completions across both engines.
    let addr2 = addr.clone();
    let results = arrow::util::threads::parallel_map((0..6u64).collect(), 3, |&i| {
        let body = format!(
            "{{\"tokens\":[{},7,11,2],\"max_tokens\":5}}",
            (i % 30) + 1
        );
        post(&addr2, "/v1/completions", &body)
    });
    for r in &results {
        let v = Json::parse(r.as_ref().expect("response")).expect("json");
        let toks = v.get("tokens").as_arr().expect("tokens");
        assert_eq!(toks.len(), 5);
        assert!(v.get("latency_s").as_f64().unwrap() > 0.0);
    }

    // Determinism: same prompt twice.
    let b = "{\"tokens\":[3,7,11,2,9,1,4,8],\"max_tokens\":4}";
    let r1 = post(&addr, "/v1/completions", b).unwrap();
    let r2 = post(&addr, "/v1/completions", b).unwrap();
    let t1 = Json::parse(&r1).unwrap().get("tokens").encode();
    let t2 = Json::parse(&r2).unwrap().get("tokens").encode();
    assert_eq!(t1, t2, "greedy decoding must be deterministic");

    // Golden check (python oracle, TINY seed 0).
    assert!(
        t1.starts_with("[1362,1879,164,1296"),
        "oracle mismatch: {t1}"
    );

    // Metrics accounting.
    let m = Json::parse(&get(&addr, "/metrics").unwrap()).unwrap();
    assert!(m.get("completed_requests").as_f64().unwrap() >= 8.0);
    assert_eq!(m.get("engines").as_arr().unwrap().len(), 2);
    // The server really runs Arrow: the shared policy's elastic pools
    // partition the engine set, live.
    let pools: Vec<u64> = m
        .get("pools")
        .as_arr()
        .expect("pools in /metrics")
        .iter()
        .filter_map(|x| x.as_u64())
        .collect();
    assert_eq!(pools.len(), 4, "pool sizes [P, D, P>D, D>P]");
    assert_eq!(pools.iter().sum::<u64>(), 2, "pools partition the engines");
    assert!(m.get("p99_ttft_s").as_f64().is_some());
    assert!(m.get("p99_tpot_s").as_f64().is_some());

    // Elastic membership (PR 3): the admin plane scales the engine set
    // at runtime through the same coordinator channel as placements.
    // Destructive endpoints demand the shared secret — an unauthenticated
    // caller is refused before any command reaches the coordinator.
    assert_eq!(m.get("live_instances").as_f64(), Some(2.0));
    let denied = post(&addr, "/admin/fail", "{\"engine\":0}").unwrap();
    assert!(denied.contains("X-Admin-Token"), "unauthenticated admin must 403: {denied}");
    let r = post_admin(&addr, "/admin/scale-out", "{}").unwrap();
    assert!(r.contains("joining"), "{r}");
    let t0 = Instant::now();
    loop {
        let m = Json::parse(&get(&addr, "/metrics").unwrap()).unwrap();
        if m.get("instances").as_f64() == Some(3.0)
            && m.get("live_instances").as_f64() == Some(3.0)
        {
            assert_eq!(m.get("engines").as_arr().unwrap().len(), 3);
            break;
        }
        assert!(t0.elapsed() < Duration::from_secs(120), "joiner never registered");
        std::thread::sleep(Duration::from_millis(250));
    }

    // Drain engine 0: no new placements, shutdown once idle, state
    // visible in /metrics.
    let r = post_admin(&addr, "/admin/drain", "{\"engine\":0}").unwrap();
    assert!(r.contains("accepted"), "{r}");
    let t0 = Instant::now();
    loop {
        let m = Json::parse(&get(&addr, "/metrics").unwrap()).unwrap();
        let states = m.get("engine_states").as_arr().expect("engine_states");
        if states[0].as_str() == Some("dead") {
            assert_eq!(m.get("live_instances").as_f64(), Some(2.0));
            break;
        }
        assert!(t0.elapsed() < Duration::from_secs(120), "drain never completed");
        std::thread::sleep(Duration::from_millis(250));
    }

    // The shrunk-but-rebalanced cluster still serves correctly.
    let r = post(&addr, "/v1/completions", b).unwrap();
    let toks = Json::parse(&r).unwrap().get("tokens").encode();
    assert!(toks.starts_with("[1362,1879,164,1296"), "post-drain oracle: {toks}");

    // Fault injection (PR 6): degrade an engine, see it in /metrics,
    // verify the cluster still answers correctly, then restore it.
    let r = post_admin(&addr, "/admin/inject", "{\"kind\":\"degrade\",\"engine\":1}").unwrap();
    assert!(r.contains("injected"), "{r}");
    let t0 = Instant::now();
    loop {
        let m = Json::parse(&get(&addr, "/metrics").unwrap()).unwrap();
        let states = m.get("engine_states").as_arr().expect("engine_states");
        if states[1].as_str() == Some("degraded") {
            // Degraded stays in the cluster — still counted live.
            assert_eq!(m.get("live_instances").as_f64(), Some(2.0));
            break;
        }
        assert!(t0.elapsed() < Duration::from_secs(30), "degrade never surfaced");
        std::thread::sleep(Duration::from_millis(100));
    }
    let r = post(&addr, "/v1/completions", b).unwrap();
    let toks = Json::parse(&r).unwrap().get("tokens").encode();
    assert!(toks.starts_with("[1362,1879,164,1296"), "degraded-cluster oracle: {toks}");
    let r = post_admin(&addr, "/admin/inject", "{\"kind\":\"restore\",\"engine\":1}").unwrap();
    assert!(r.contains("injected"), "{r}");

    // Error paths.
    let bad = post(&addr, "/v1/completions", "{\"max_tokens\":3}").unwrap();
    assert!(bad.contains("error"));
    // Validation (PR 6): present-but-nonsense max_tokens is a 400, not a
    // silently substituted default.
    let bad = post(&addr, "/v1/completions", "{\"tokens\":[1,2],\"max_tokens\":0}").unwrap();
    assert!(bad.contains("max_tokens"), "{bad}");
    let bad = post(
        &addr,
        "/v1/completions",
        "{\"tokens\":[1,2],\"max_tokens\":9999999}",
    )
    .unwrap();
    assert!(bad.contains("max_tokens"), "{bad}");
    let nf = get(&addr, "/nope").unwrap();
    assert!(nf.contains("not found"));
    let bad = post_admin(&addr, "/admin/drain", "{}").unwrap();
    assert!(bad.contains("error"), "{bad}");
    let bad = post_admin(&addr, "/admin/inject", "{\"kind\":\"meteor\",\"engine\":0}").unwrap();
    assert!(bad.contains("error"), "{bad}");
    let denied = post(&addr, "/admin/inject", "{\"kind\":\"degrade\",\"engine\":0}").unwrap();
    assert!(denied.contains("X-Admin-Token"), "{denied}");

    // Flight recorder (PR 9): the journal counted every decision this
    // test provoked, and recording never dropped under this load.
    let m = Json::parse(&get(&addr, "/metrics").unwrap()).unwrap();
    assert!(
        m.get("journal_events").as_f64().unwrap() > 0.0,
        "journal must have recorded scheduling decisions"
    );
    assert_eq!(m.get("journal_dropped").as_f64(), Some(0.0));

    // Graceful shutdown (PR 9): token-guarded, drains the engines,
    // flushes the journal, and stops the accept loop.
    let denied = post(&addr, "/admin/shutdown", "{}").unwrap();
    assert!(denied.contains("X-Admin-Token"), "{denied}");
    let r = post_admin(&addr, "/admin/shutdown", "{}").unwrap();
    assert!(r.contains("shutting down"), "{r}");
    let t0 = Instant::now();
    while get(&addr, "/healthz").is_some() {
        assert!(t0.elapsed() < Duration::from_secs(60), "server never stopped");
        std::thread::sleep(Duration::from_millis(250));
    }

    // Offline replay: every journaled decision re-derives identically
    // through a fresh policy instance. Drain-time records may race the
    // shutdown flush, so a torn tail is acceptable — divergence is not.
    let report = arrow::replay::verify::verify_journal(
        &journal,
        &arrow::replay::verify::VerifyOptions::default(),
    )
    .expect("live journal must verify");
    assert!(
        report.ok(),
        "live journal diverged on replay: {:?}",
        report.detail
    );
    assert!(report.verified > 0, "journal must contain decisions");
    let _ = std::fs::remove_file(&journal);
}
