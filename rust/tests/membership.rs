//! Elastic-membership integration tests (PR 3 tentpole acceptance).
//!
//! The two acceptance criteria from the issue, plus the rolling-restart
//! drill:
//!
//! * a scenario that loses a decode instance mid-burst completes **all**
//!   requests — re-queued work finishes, no panic;
//! * the spike-scale-out scenario shows **strictly better p99 TTFT**
//!   than the fixed-membership run in the same sweep.

use arrow::costmodel::CostModel;
use arrow::metrics::SloReport;
use arrow::request::Request;
use arrow::scenarios::{build, decode_node_failure, rolling_restart, spike_scale_out, System};
use arrow::trace::Trace;
use arrow::util::rng::Rng;

const TTFT_SLO: f64 = 3.0;
const TPOT_SLO: f64 = 0.1;

/// Calm baseline traffic with a hard prefill-heavy burst at t = 20..30s —
/// the temporal-misalignment spike of Fig. 4, cranked until a small fixed
/// cluster backlogs badly.
fn burst_trace(seed: u64) -> Trace {
    let mut rng = Rng::new(seed);
    let mut reqs = Vec::new();
    let mut id = 0u64;
    for s in 0..120 {
        let t = s as f64;
        for _ in 0..2 {
            reqs.push(Request::new(
                id,
                t + rng.f64(),
                rng.int_range(500, 3_000) as u32,
                rng.int_range(50, 200) as u32,
            ));
            id += 1;
        }
        if (20..30).contains(&s) {
            for _ in 0..25 {
                reqs.push(Request::new(
                    id,
                    t + rng.f64(),
                    rng.int_range(8_000, 40_000) as u32,
                    rng.int_range(20, 120) as u32,
                ));
                id += 1;
            }
        }
    }
    Trace::new("membership-burst", reqs)
}

#[test]
fn losing_a_decode_instance_mid_burst_completes_all_requests() {
    let trace = burst_trace(3);
    // Kill one seed-decode instance right at the burst peak: its running
    // decodes lose their KV, its queued work evaporates — everything must
    // be re-queued onto the survivors and still finish.
    let cl = decode_node_failure(6, 1, &CostModel::h800_llama8b(), TTFT_SLO, TPOT_SLO, 25.0);
    let res = cl.run(&trace);
    let rep = SloReport::from_records(&res.records, TTFT_SLO, TPOT_SLO, trace.duration());
    assert_eq!(rep.n_failed, 0, "no request may be dropped by the failure");
    assert_eq!(rep.n_finished, rep.n_requests, "re-queued work must finish");
    // Token conservation survives the restart path: finished requests
    // emitted exactly output_len tokens despite mid-decode retries.
    for rec in &res.records {
        assert_eq!(rec.token_times.len(), rec.output_len as usize, "req {}", rec.id);
    }
    // The dead instance (table slot 5) did no post-mortem work.
    for rec in &res.records {
        if rec.decode_instance.map_or(false, |i| i.0 == 5) {
            assert!(*rec.token_times.last().unwrap() <= 25.0 + 1e-9);
        }
    }
}

#[test]
fn correlated_decode_failure_still_completes() {
    // Two of six instances die together (rack loss) — harsher than the
    // acceptance minimum but the same invariant: nothing is lost.
    let trace = burst_trace(11);
    let cl = decode_node_failure(6, 2, &CostModel::h800_llama8b(), TTFT_SLO, TPOT_SLO, 26.0);
    let res = cl.run(&trace);
    assert!(
        res.records.iter().all(|r| r.finished()),
        "correlated failure must not lose requests"
    );
}

#[test]
fn spike_scale_out_strictly_beats_fixed_membership_p99_ttft() {
    let trace = burst_trace(7);
    let base = CostModel::h800_llama8b();
    let d = trace.duration();
    // Same sweep, two membership regimes: a fixed 4-GPU cluster vs the
    // same 4 GPUs plus 4 spares joining as the spike lands.
    let fixed = build(System::Arrow, 4, &base, TTFT_SLO, TPOT_SLO, false).run(&trace);
    let elastic = spike_scale_out(4, 4, &base, TTFT_SLO, TPOT_SLO, 20.0).run(&trace);
    let rep_fixed = SloReport::from_records(&fixed.records, TTFT_SLO, TPOT_SLO, d);
    let rep_elastic = SloReport::from_records(&elastic.records, TTFT_SLO, TPOT_SLO, d);

    assert_eq!(
        rep_elastic.n_finished, rep_elastic.n_requests,
        "elastic run completes everything"
    );
    assert!(
        rep_elastic.p99_ttft < rep_fixed.p99_ttft,
        "scale-out must strictly improve p99 TTFT: elastic {} vs fixed {}",
        rep_elastic.p99_ttft,
        rep_fixed.p99_ttft
    );
    assert!(
        rep_elastic.slo_attainment >= rep_fixed.slo_attainment,
        "scale-out must not reduce SLO attainment: {} vs {}",
        rep_elastic.slo_attainment,
        rep_fixed.slo_attainment
    );
    // The joiners really absorbed part of the spike.
    let spares_used = elastic.records.iter().any(|r| {
        r.prefill_instance.map_or(false, |i| i.0 >= 4)
            || r.decode_instance.map_or(false, |i| i.0 >= 4)
    });
    assert!(spares_used, "spare instances never received work");
}

#[test]
fn rolling_restart_loses_nothing_and_really_restarts() {
    let trace = burst_trace(5);
    // Drain each of 6 instances in turn (drain at 10+15i, rejoin 5 s
    // after each drain completes).
    let cl = rolling_restart(
        6,
        &CostModel::h800_llama8b(),
        TTFT_SLO,
        TPOT_SLO,
        10.0,
        15.0,
        5.0,
    );
    let res = cl.run(&trace);
    assert!(
        res.records.iter().all(|r| r.finished()),
        "a rolling restart is graceful: every request must finish"
    );
    // The drill must actually take instances down and bring them back —
    // a silently-cancelled drain would leave the live count flat at 6.
    let first_dip = res
        .timeline
        .iter()
        .position(|s| s.live < 6)
        .expect("no instance ever left the cluster — the restarts never happened");
    assert!(
        res.timeline[first_dip..].iter().any(|s| s.live == 6),
        "the cluster never recovered to full strength after a restart"
    );
}
