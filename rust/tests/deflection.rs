//! Deflection conformance tier (PR 10 satellite).
//!
//! [`DeflectPolicy`] is Arrow plus exactly one extra move — chunk-
//! colocating a small prefill onto a decode instance when the prefill
//! side is pressed — so its contract is "Arrow, except where the
//! deflection paper says otherwise". Five properties pin that down:
//!
//! 1. **Quiescent bit-identity** — with no prefill pressure the wrapper
//!    delegates every decision, so a light-load schedule is
//!    bit-identical to plain Arrow's (placements, token times, flips,
//!    iterations, event counts).
//! 2. **No decode displacement** — a deflected prefill shares mixed
//!    iterations with the target's in-progress decode head; the decode
//!    batch keeps emitting a token every iteration (decode priority +
//!    `iter_time_budget` chunking, the PR-1 engine contract the
//!    deflection design leans on).
//! 3. **Interference guard** — a target past the TPOT budget refuses
//!    deflection, identically through the simulator borrow and the
//!    live-server snapshot.
//! 4. **Size cap** — an oversized prefill is never deflected: under the
//!    exact same pressure the wrapper's decision equals plain Arrow's.
//! 5. **Hand-walked burst** — with the prefill pool pressed by a long
//!    backlog, N small prefills deflect and complete their prefills
//!    strictly before the pressed queue's own predicted drain window
//!    (the window a flip-based resolution necessarily waits on) closes
//!    — and no flip is burned doing it.

use arrow::coordinator::arrow::{ArrowConfig, ArrowPolicy};
use arrow::costmodel::CostModel;
use arrow::engine::{Produced, SimInstance};
use arrow::request::{InstanceId, Request, RequestId};
use arrow::scenarios::{build, System};
use arrow::sched::{DeflectConfig, DeflectPolicy, Policy, ProfileSource, DEFAULT_CHUNK_TOKENS};
use arrow::server::view::mirror_sim_instances;
use arrow::sim::SimView;
use arrow::trace::synthetic::smoke;

const TTFT_SLO: f64 = 3.0;
const TPOT_SLO: f64 = 0.1;

fn cluster(n: usize) -> Vec<SimInstance> {
    (0..n)
        .map(|i| SimInstance::new(InstanceId(i), CostModel::h800_llama8b()))
        .collect()
}

fn deflect_policy(insts: &[SimInstance]) -> DeflectPolicy {
    let n = insts.len();
    let mut p = DeflectPolicy::new(DeflectConfig::new(TTFT_SLO, TPOT_SLO, n), n);
    p.init(&SimView(insts));
    p
}

/// Backlog every seed prefill instance far past any SLO (the pressure
/// regime in which Arrow hunts for a flip and deflection triggers).
fn press_prefill_pool(insts: &mut [SimInstance], n_prefill: usize) {
    for inst in insts.iter_mut().take(n_prefill) {
        for r in 0..4 {
            inst.enqueue_prefill(RequestId(900 + r), 100_000);
        }
    }
}

fn small(id: u64, input: u32) -> Request {
    Request::new(id, 0.0, input, 10)
}

// ---------------------------------------------------------------------------
// 1. Quiescent bit-identity to Arrow
// ---------------------------------------------------------------------------

#[test]
fn quiescent_schedule_is_bit_identical_to_arrow() {
    // Light load on 8 instances: predicted queue delays never approach
    // the TTFT SLO, so the deflection trigger must never fire and the
    // wrapper is a transparent proxy — down to the last token-time bit.
    let base = CostModel::h800_llama8b();
    let trace = smoke(150, 2).generate(3);
    let a = build(System::Arrow, 8, &base, 2.0, 0.1, false).run(&trace);
    let d = build(System::Deflect, 8, &base, 2.0, 0.1, false).run(&trace);
    assert_eq!(a.records.len(), d.records.len());
    for (ra, rd) in a.records.iter().zip(&d.records) {
        assert_eq!(ra.prefill_instance, rd.prefill_instance, "req {}", ra.id);
        assert_eq!(ra.decode_instance, rd.decode_instance, "req {}", ra.id);
        assert_eq!(ra.state, rd.state, "req {}", ra.id);
        assert_eq!(ra.token_times.len(), rd.token_times.len(), "req {}", ra.id);
        for (ta, td) in ra.token_times.iter().zip(&rd.token_times) {
            assert_eq!(
                ta.to_bits(),
                td.to_bits(),
                "req {}: quiescent deflect drifted from Arrow",
                ra.id
            );
        }
    }
    assert_eq!(a.total_flips, d.total_flips, "flip decisions diverged");
    assert_eq!(a.total_iterations, d.total_iterations);
    assert_eq!(a.events_processed, d.events_processed);
}

// ---------------------------------------------------------------------------
// 2. Deflected prefill never displaces the in-progress decode head
// ---------------------------------------------------------------------------

#[test]
fn deflected_prefill_never_displaces_in_progress_decode() {
    // n=2: one prefill instance (0, pressed), one decode instance (1)
    // with a decode in flight. The deflected prefill must share mixed
    // iterations with that decode — which keeps emitting a token every
    // single iteration until the prefill completes.
    let mut insts = cluster(2);
    insts[1].iter_time_budget = Some(0.8 * TPOT_SLO);
    let mut p = deflect_policy(&insts);
    press_prefill_pool(&mut insts, 1);
    let decode_id = RequestId(500);
    assert!(insts[1].try_reserve_kv(4_000));
    insts[1].enqueue_decode(decode_id, 4_000, 50);

    let req = small(1, 1_200);
    let target = p.place_prefill(0.0, &req, &SimView(&insts));
    assert_eq!(target, InstanceId(1), "small prefill deflects to the decode instance");
    assert_eq!(p.deflection_count(), 1);
    insts[1].enqueue_prefill(RequestId(1), req.input_len);

    let mut now = 0.0;
    let mut prefill_done = false;
    for _ in 0..64 {
        let plan = insts[1]
            .plan_iteration()
            .expect("decode + deflected prefill leave work to do");
        // The decode head is in every mixed iteration, and the deflected
        // chunk rides along rather than displacing it.
        assert_eq!(plan.decode_reqs, 1, "decode head dropped from the batch");
        now += plan.duration;
        let produced = insts[1].finish_iteration(&plan, now);
        assert!(
            produced
                .iter()
                .any(|ev| matches!(ev, Produced::Token { id } | Produced::FinalToken { id, .. } if *id == decode_id)),
            "decode head skipped a token while the deflected prefill ran"
        );
        if produced
            .iter()
            .any(|ev| matches!(ev, Produced::PrefillDone { id, .. } if *id == RequestId(1)))
        {
            prefill_done = true;
            break;
        }
    }
    assert!(prefill_done, "deflected prefill never completed");
}

// ---------------------------------------------------------------------------
// 3. Interference guard, across both adapters
// ---------------------------------------------------------------------------

#[test]
fn interference_guard_holds_across_adapters() {
    let mut insts = cluster(4);
    let mut sim_p = deflect_policy(&insts);
    let mut srv_p = deflect_policy(&insts);
    press_prefill_pool(&mut insts, 2);
    // Every decode-capable target reports token intervals past the TPOT
    // budget: deflection is off the table, and the wrapped Arrow decides
    // — identically through both adapters.
    for inst in insts.iter_mut().skip(2) {
        inst.seed_token_interval(0.5); // >> 0.1s TPOT SLO
    }
    for step in 0..8u64 {
        let r = small(step, 1_000);
        let snap = mirror_sim_instances(&insts);
        let a = sim_p.place_prefill(step as f64, &r, &SimView(&insts));
        let b = srv_p.place_prefill(step as f64, &r, &snap);
        assert_eq!(a, b, "step {step}: guard decision diverged across adapters");
        assert_eq!(sim_p.deflection_count(), 0, "guard must block deflection");
        assert_eq!(srv_p.deflection_count(), 0);
        assert_eq!(sim_p.pool_sizes(), srv_p.pool_sizes(), "step {step}");
        assert_eq!(sim_p.flip_count(), srv_p.flip_count(), "step {step}");
    }
}

// ---------------------------------------------------------------------------
// 4. Oversized prefills follow Arrow exactly
// ---------------------------------------------------------------------------

#[test]
fn oversized_prefill_is_never_deflected_and_matches_arrow() {
    // Two identically initialized policies over identical state: for a
    // request past the deflection cap, the wrapper must reproduce plain
    // Arrow's decision (flip and all), not merely "not deflect".
    let mut insts = cluster(4);
    let mut wrapped = deflect_policy(&insts);
    let mut plain = ArrowPolicy::new(ArrowConfig::new(TTFT_SLO, TPOT_SLO, 4), 4);
    plain.init(&SimView(&insts));
    press_prefill_pool(&mut insts, 2);

    let big = small(1, DEFAULT_CHUNK_TOKENS + 1);
    let a = wrapped.place_prefill(0.0, &big, &SimView(&insts));
    let b = plain.place_prefill(0.0, &big, &SimView(&insts));
    assert_eq!(a, b, "oversized request must fall through to Arrow verbatim");
    assert_eq!(wrapped.deflection_count(), 0);
    assert_eq!(wrapped.flip_count(), plain.flip_count(), "flip decisions must match");
    assert_eq!(wrapped.pool_sizes(), plain.pool_sizes());
}

// ---------------------------------------------------------------------------
// 5. Hand-walked burst: deflection beats the flip-drain window
// ---------------------------------------------------------------------------

#[test]
fn hand_walked_burst_completes_small_prefills_inside_the_drain_window() {
    // Pools seed [0,1] prefill / [2,3] decode. Both prefill instances are
    // pressed with 400k tokens of backlog; the predicted drain of that
    // backlog is the window any flip-based resolution waits on (a freshly
    // flipped instance only relieves requests that queue *behind* the
    // decision, and the pressed queues keep draining meanwhile). Three
    // small prefills deflect instead — and all three complete while that
    // window is still open, without burning a single flip.
    let n = 4;
    let mut insts = cluster(n);
    for inst in insts.iter_mut() {
        inst.iter_time_budget = Some(0.8 * TPOT_SLO);
    }
    let mut p = deflect_policy(&insts);
    press_prefill_pool(&mut insts, 2);
    assert_eq!(p.pools().sizes(), [2, 2, 0, 0]);

    // The drain window, priced by the same fitted predictor the policy
    // uses: the shorter of the two pressed queues.
    let profile = SimView(&insts);
    let window = (0..2)
        .map(|i| profile.fit_predictor(i).queue_delay_view(&SimView(&insts), i))
        .fold(f64::INFINITY, f64::min);
    assert!(
        window > TTFT_SLO,
        "backlog must exceed the SLO for the burst to be pressure at all"
    );

    // Deflect N small prefills; each lands on a decode instance and the
    // load metric (resident + queued tokens) spreads them.
    let n_small = 3u64;
    let mut targets = Vec::new();
    for id in 0..n_small {
        let r = small(id, 1_500);
        let t = p.place_prefill(0.0, &r, &SimView(&insts));
        assert!(t.0 >= 2, "small prefill {id} must deflect, got {t}");
        insts[t.0].enqueue_prefill(RequestId(id), r.input_len);
        targets.push(t);
    }
    assert_eq!(p.deflection_count(), n_small);
    assert_eq!(p.flip_count(), 0, "deflection must not burn a flip");
    assert_eq!(p.pools().sizes(), [2, 2, 0, 0], "pools untouched");
    assert!(
        targets.iter().any(|t| *t != targets[0]),
        "consecutive deflections must spread over the decode pool"
    );

    // Hand-walk the decode instances until every deflected prefill has
    // produced its first token; each instance's clock advances by its
    // own iteration durations.
    let mut clock = [0.0f64; 4];
    let mut done = [false; 3];
    for _ in 0..256 {
        if done.iter().all(|&d| d) {
            break;
        }
        for i in 2..n {
            if let Some(plan) = insts[i].plan_iteration() {
                clock[i] += plan.duration;
                let t = clock[i];
                for ev in insts[i].finish_iteration(&plan, t) {
                    if let Produced::PrefillDone { id, .. } = ev {
                        if (id.0 as usize) < done.len() {
                            done[id.0 as usize] = true;
                            assert!(
                                t < window,
                                "deflected prefill {id} completed at {t:.3}s, after \
                                 the {window:.3}s flip-drain window closed"
                            );
                        }
                    }
                }
            }
        }
    }
    assert!(done.iter().all(|&d| d), "not every deflected prefill completed");
}
