//! Flight-recorder durability and determinism contract (PR 9).
//!
//! Property-tests the record→replay loop end to end: journals recorded
//! by the demo mini-coordinator must round-trip byte-identically across
//! seeds and policies, every recorded decision must re-derive to the
//! same placement/pool-state/flip-count through both the server-view
//! oracle and (where representable) the simulator oracle, and a torn or
//! corrupted tail must replay the intact prefix with an explicit cut
//! report — never a panic, never silent divergence.

use std::path::PathBuf;

use arrow::replay::demo::{record_demo, DemoConfig};
use arrow::replay::verify::{verify_journal, VerifyOptions};
use arrow::replay::{load, Record};

fn temp_path(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!(
        "arrow-replay-test-{tag}-{}-{:?}.arwj",
        std::process::id(),
        std::thread::current().id()
    ))
}

/// Record a demo journal and return its raw bytes (file is removed).
fn demo_bytes(cfg: &DemoConfig, tag: &str) -> Vec<u8> {
    let path = temp_path(tag);
    record_demo(&path, cfg).expect("record demo journal");
    let bytes = std::fs::read(&path).expect("read journal");
    let _ = std::fs::remove_file(&path);
    bytes
}

#[test]
fn record_replay_round_trips_across_seeds_and_policies() {
    for policy in ["arrow-slo-aware", "all-to-one", "static-split"] {
        for seed in [1u64, 7, 42] {
            let cfg = DemoConfig {
                seed,
                steps: 200,
                policy: policy.into(),
                ..Default::default()
            };
            let path = temp_path(&format!("prop-{policy}-{seed}"));
            record_demo(&path, &cfg).expect("record");
            let report = verify_journal(
                &path,
                &VerifyOptions {
                    sim_oracle: true,
                    max_reported: 16,
                },
            )
            .expect("verify");
            assert!(
                report.ok(),
                "{policy}/seed {seed} diverged: {:?}",
                report.detail
            );
            assert_eq!(
                report.verified, report.records,
                "{policy}/seed {seed}: every record must be re-derived"
            );
            assert_eq!(
                report.sim_verified + report.sim_skipped,
                report.verified,
                "{policy}/seed {seed}: sim oracle must account for every decision"
            );
            assert!(report.torn.is_none());
            assert!(report.stopped_at_gap.is_none());
            assert_eq!(report.dropped, 0);
            let _ = std::fs::remove_file(&path);
        }
    }
}

#[test]
fn recording_is_byte_deterministic_per_config() {
    let cfg = DemoConfig {
        seed: 9,
        steps: 150,
        ..Default::default()
    };
    let a = demo_bytes(&cfg, "det-a");
    let b = demo_bytes(&cfg, "det-b");
    assert_eq!(a, b, "same config must record identical bytes");
    let c = demo_bytes(
        &DemoConfig {
            seed: 10,
            ..cfg
        },
        "det-c",
    );
    assert_ne!(a, c, "a different seed must record a different journal");
}

#[test]
fn truncated_tail_replays_the_intact_prefix_and_reports_the_cut() {
    let cfg = DemoConfig {
        seed: 3,
        steps: 120,
        ..Default::default()
    };
    let bytes = demo_bytes(&cfg, "trunc-src");
    let whole = load_from_bytes(&bytes, "trunc-whole");
    let total = whole.records.len();
    assert!(total > 10, "need a non-trivial journal to truncate");

    // Chop mid-record: the final record loses part of its body.
    for cut in [3usize, 9, 15] {
        let torn_bytes = &bytes[..bytes.len() - cut];
        let path = temp_path(&format!("trunc-{cut}"));
        std::fs::write(&path, torn_bytes).expect("write torn journal");
        let j = load(&path).expect("torn journal must still load");
        let t = j.torn.as_ref().expect("cut must be reported");
        assert!(
            (t.offset as usize) < bytes.len(),
            "cut offset {} past file end",
            t.offset
        );
        assert_eq!(
            j.records.len(),
            total - 1,
            "exactly the torn final record is dropped (cut {cut})"
        );
        assert_eq!(j.records, whole.records[..total - 1]);

        // The intact prefix still verifies cleanly.
        let report = verify_journal(&path, &VerifyOptions::default()).expect("verify prefix");
        assert!(report.ok(), "prefix diverged: {:?}", report.detail);
        assert!(report.torn.is_some(), "verify must surface the cut");
        assert_eq!(report.verified, (total - 1) as u64);
        let _ = std::fs::remove_file(&path);
    }
}

#[test]
fn corrupted_tail_is_cut_at_the_checksum_not_trusted() {
    let cfg = DemoConfig {
        seed: 4,
        steps: 120,
        ..Default::default()
    };
    let mut bytes = demo_bytes(&cfg, "corrupt-src");
    let whole = load_from_bytes(&bytes, "corrupt-whole");
    let total = whole.records.len();

    // Flip the last payload byte: the final record's checksum no longer
    // matches, so replay must cut there and keep the prefix.
    let last = bytes.len() - 1;
    bytes[last] ^= 0xff;
    let path = temp_path("corrupt");
    std::fs::write(&path, &bytes).expect("write corrupted journal");
    let j = load(&path).expect("corrupted tail must still load");
    let t = j.torn.as_ref().expect("corruption must be reported");
    assert!(t.reason.contains("checksum"), "reason: {}", t.reason);
    assert_eq!(j.records.len(), total - 1);
    assert_eq!(j.records, whole.records[..total - 1]);
    let _ = std::fs::remove_file(&path);
}

#[test]
fn mid_file_corruption_truncates_everything_after_it() {
    let cfg = DemoConfig {
        seed: 5,
        steps: 120,
        ..Default::default()
    };
    let mut bytes = demo_bytes(&cfg, "midflip-src");
    let whole = load_from_bytes(&bytes, "midflip-whole");
    let total = whole.records.len();

    // Flip one byte around the middle of the file. Whatever field it
    // lands in (length, checksum, payload), nothing at or after the
    // damaged record may be trusted — and nothing before it may be lost.
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x01;
    let path = temp_path("midflip");
    std::fs::write(&path, &bytes).expect("write corrupted journal");
    let j = load(&path).expect("mid-file corruption must still load");
    assert!(j.torn.is_some(), "corruption must be reported");
    assert!(j.records.len() < total);
    assert_eq!(j.records[..], whole.records[..j.records.len()]);
    let _ = std::fs::remove_file(&path);
}

#[test]
fn header_damage_is_a_hard_error_not_a_guess() {
    let cfg = DemoConfig {
        seed: 6,
        steps: 40,
        ..Default::default()
    };
    let bytes = demo_bytes(&cfg, "header-src");

    // Bad magic: not a journal at all.
    let mut bad = bytes.clone();
    bad[0] ^= 0xff;
    let path = temp_path("header-magic");
    std::fs::write(&path, &bad).expect("write");
    assert!(load(&path).unwrap_err().contains("magic"));
    let _ = std::fs::remove_file(&path);

    // Future version: refuse loudly (format versioning), never
    // misinterpret a newer layout as this one.
    let mut future = bytes.clone();
    future[4] = 0xEE;
    let path = temp_path("header-version");
    std::fs::write(&path, &future).expect("write");
    assert!(load(&path).unwrap_err().contains("journal format"));
    let _ = std::fs::remove_file(&path);

    // Header-only file: nothing intact to replay.
    let path = temp_path("header-only");
    std::fs::write(&path, &bytes[..8]).expect("write");
    assert!(load(&path).is_err());
    let _ = std::fs::remove_file(&path);
}

#[test]
fn membership_churn_still_replays_exactly() {
    // Heavier churn than the default config: more steps on a smaller
    // cluster makes joins/drains/failures (and the post-failure
    // re-dispatch records) much denser in the journal.
    let cfg = DemoConfig {
        seed: 11,
        steps: 500,
        engines: 2,
        membership: true,
        ..Default::default()
    };
    let path = temp_path("churn");
    record_demo(&path, &cfg).expect("record");
    let j = load(&path).expect("load");
    assert!(
        j.records
            .iter()
            .any(|r| matches!(r, Record::Membership { .. })),
        "churn config must actually journal membership events"
    );
    let report = verify_journal(&path, &VerifyOptions::default()).expect("verify");
    assert!(report.ok(), "churn diverged: {:?}", report.detail);
    assert_eq!(report.verified, report.records);
    let _ = std::fs::remove_file(&path);
}

/// Load a journal from raw bytes via a scratch file.
fn load_from_bytes(bytes: &[u8], tag: &str) -> arrow::replay::LoadedJournal {
    let path = temp_path(tag);
    std::fs::write(&path, bytes).expect("write scratch journal");
    let j = load(&path).expect("load scratch journal");
    let _ = std::fs::remove_file(&path);
    j
}
