//! Heterogeneous-cluster integration (paper §8 Discussion): Arrow
//! schedules over instances with different hardware speeds using
//! per-instance profiled TTFT predictors — placement decisions must
//! reflect each instance's own curve.
//!
//! Also validates Insight 1 end-to-end: in a prefill-only deterministic
//! setting, the predictor's TTFT estimate at dispatch time must match the
//! simulator's realized TTFT (paper Eq. 1–2).

use arrow::coordinator::arrow::{ArrowConfig, ArrowPolicy};
use arrow::coordinator::predictor::TtftPredictor;
use arrow::costmodel::CostModel;
use arrow::engine::SimInstance;
use arrow::metrics::SloReport;
use arrow::request::{InstanceId, Request};
use arrow::sched::{ClusterView, Policy};
use arrow::sim::{Cluster, SimConfig, SimView};
use arrow::trace::synthetic::smoke;
use arrow::trace::Trace;

/// 2 fast (TP=2-grade) + 2 slow instances.
fn hetero_instances() -> Vec<SimInstance> {
    let base = CostModel::h800_llama8b();
    let fast = base.with_tensor_parallel(2, 0.9);
    (0..4)
        .map(|i| {
            let cost = if i % 2 == 0 { fast.clone() } else { base.clone() };
            SimInstance::new(InstanceId(i), cost)
        })
        .collect()
}

#[test]
fn per_instance_predictors_reflect_speed() {
    let insts = hetero_instances();
    let mut p = ArrowPolicy::new(ArrowConfig::new(3.0, 0.1, 4), 4);
    p.init(&SimView(&insts));
    // Equal queues: the policy must place the next prefill on a FAST
    // instance, because its predicted delay is smaller.
    let mut insts = insts;
    for i in 0..4 {
        insts[i].enqueue_prefill(arrow::request::RequestId(i as u64), 20_000);
    }
    let t = p.place_prefill(0.0, &Request::new(9, 0.0, 5_000, 10), &SimView(&insts));
    assert!(t.0 % 2 == 0, "picked slow instance {t} despite equal queues");
}

#[test]
fn hetero_cluster_serves_workload() {
    let insts = hetero_instances();
    let policy = ArrowPolicy::new(ArrowConfig::new(2.0, 0.1, 4), 4);
    let cl = Cluster::new(insts, Box::new(policy), SimConfig::default());
    let trace = smoke(300, 2).generate(5);
    let res = cl.run(&trace);
    let rep = SloReport::from_records(&res.records, 2.0, 0.1, trace.duration());
    assert_eq!(rep.n_finished + rep.n_failed, rep.n_requests);
    assert!(
        rep.n_finished as f64 >= 0.99 * rep.n_requests as f64,
        "finished {}/{}",
        rep.n_finished,
        rep.n_requests
    );
}

#[test]
fn ttft_prediction_matches_realized_prefill_only() {
    // Insight 1 / Eq. 1-2: with a single prefill instance, no decode
    // phase interference (output_len = 1) and requests arriving into a
    // known queue, predicted TTFT ≈ realized TTFT.
    let cost = CostModel::h800_llama8b();
    let inst = SimInstance::new(InstanceId(0), cost.clone());
    let predictor = TtftPredictor::profile(&cost, inst.chunk_tokens);

    // Back-to-back arrivals at t=0: queue delay for request i is the sum
    // of requests 0..i's prefill times.
    let lens = [4_000u32, 12_000, 2_000, 30_000];
    let reqs: Vec<Request> = lens
        .iter()
        .enumerate()
        .map(|(i, &l)| Request::new(i as u64, 0.0, l, 1))
        .collect();
    let trace = Trace::new("pred-check", reqs);

    struct ToZero;
    impl Policy for ToZero {
        fn name(&self) -> &'static str {
            "to-zero"
        }
        fn place_prefill(&mut self, _: f64, _: &Request, _: &dyn ClusterView) -> InstanceId {
            InstanceId(0)
        }
        fn place_decode(
            &mut self,
            _: f64,
            _: &Request,
            p: InstanceId,
            _: &dyn ClusterView,
        ) -> InstanceId {
            p
        }
    }

    let cl = Cluster::new(vec![inst], Box::new(ToZero), SimConfig::default());
    let res = cl.run(&trace);

    // Predicted TTFT for request i = sum of predicted prefill times of
    // requests 0..=i (paper Eq. 2 with simultaneous arrivals).
    let mut queue: Vec<(u32, u32)> = Vec::new();
    for (i, &len) in lens.iter().enumerate() {
        let predicted = predictor.predict_ttft(len, &queue);
        let realized = res.records[i].ttft().expect("finished");
        let rel = (predicted - realized).abs() / realized;
        assert!(
            rel < 0.15,
            "req {i} (len {len}): predicted {predicted:.3}s realized {realized:.3}s ({:.0}% off)",
            rel * 100.0
        );
        queue.push((len, len));
    }
}

#[test]
fn prediction_error_grows_with_decode_interference() {
    // The paper's §5.3 note: D→P instances make TTFT predictions less
    // accurate because ongoing decodes share iterations. Verify the
    // direction: realized >= predicted when decode work is present.
    let cost = CostModel::h800_llama8b();
    let inst = SimInstance::new(InstanceId(0), cost.clone());
    let predictor = TtftPredictor::profile(&cost, inst.chunk_tokens);

    struct ToZero;
    impl Policy for ToZero {
        fn name(&self) -> &'static str {
            "to-zero"
        }
        fn place_prefill(&mut self, _: f64, _: &Request, _: &dyn ClusterView) -> InstanceId {
            InstanceId(0)
        }
        fn place_decode(
            &mut self,
            _: f64,
            _: &Request,
            p: InstanceId,
            _: &dyn ClusterView,
        ) -> InstanceId {
            p
        }
    }

    // Request 0 becomes a long-running decode job; request 1's prefill
    // arrives while it decodes and shares iterations with it.
    let trace = Trace::new(
        "interfered",
        vec![
            Request::new(0, 0.0, 2_000, 50_000),
            Request::new(1, 30.0, 8_000, 1),
        ],
    );
    let predicted = predictor.predict_ttft(8_000, &[]);
    let cl = Cluster::new(vec![inst], Box::new(ToZero), SimConfig::default());
    let res = cl.run(&trace);
    let realized = res.records[1].ttft().unwrap();
    assert!(
        realized > predicted,
        "decode interference must slow prefill: predicted {predicted:.3}s realized {realized:.3}s"
    );
}
