//! Streaming sweep path equivalence (PR 7 tentpole acceptance).
//!
//! The simulator now has three front doors over one event loop:
//!
//! * `run` — calendar-cursor arrivals over a materialized trace,
//!   retained records (the default);
//! * `run_reference` — the legacy pre-pushed heap, the PR-1 oracle;
//! * `run_streamed` — lazy arrivals from an `ArrivalSource`, records
//!   folded incrementally and handed to a sink, `token_times` never
//!   retained.
//!
//! Contract pinned here: on the same arrivals and seed, all three produce
//! the *same simulation* — identical event counts, identical per-request
//! placements and token timing (bit-for-bit where retained, via the
//! incremental folds where streamed) — for every Table-1 catalog workload
//! plus the smoke workload, and under chaos (membership changes, fault
//! plans, transfer retries). The constant-memory `StreamingSlo` sink must
//! agree with the exact `SloReport::from_records` oracle: exact fields
//! bit-identical, sketched percentiles inside an explicit band.

use arrow::costmodel::CostModel;
use arrow::fault::{FaultPlan, TransferRetryPolicy};
use arrow::metrics::{SloReport, StreamingSlo};
use arrow::request::RequestRecord;
use arrow::scenarios::{build, System};
use arrow::sim::{Cluster, MembershipChange, SimConfig, SimResult};
use arrow::trace::catalog;
use arrow::trace::stream::{SyntheticSource, TraceSource};
use arrow::trace::Trace;

const SEED: u64 = 42;

/// Clip horizon keeping per-workload runtime test-tier sized while still
/// covering hundreds of requests per trace (azure_conv ~5.4 req/s).
const CLIP_SECONDS: f64 = 60.0;

fn catalog_traces() -> Vec<(String, Trace, f64, f64)> {
    let mut out = Vec::new();
    for name in catalog::names() {
        let w = catalog::by_name(name).unwrap();
        let trace = w.generate(SEED).clip_seconds(CLIP_SECONDS);
        assert!(!trace.is_empty(), "{name} clipped to nothing");
        out.push((name.to_string(), trace, w.ttft_slo, w.tpot_slo));
    }
    out
}

fn run_streamed_collect(cl: Cluster, trace: &Trace) -> (SimResult, Vec<RequestRecord>) {
    let mut src = TraceSource::new(trace);
    let mut recs = Vec::new();
    let res = cl.run_streamed(&mut src, &mut |r| recs.push(r));
    (res, recs)
}

/// The streamed record must be the retained record minus the retained
/// token-time vector: same identity, same placements, same folded
/// latency aggregates to the bit.
fn assert_rec_equivalent(ctx: &str, retained: &RequestRecord, streamed: &RequestRecord) {
    assert_eq!(retained.id, streamed.id, "{ctx}: id");
    assert_eq!(retained.state, streamed.state, "{ctx}: state");
    assert_eq!(retained.shed, streamed.shed, "{ctx}: shed reason");
    assert_eq!(
        retained.prefill_instance, streamed.prefill_instance,
        "{ctx}: prefill placement"
    );
    assert_eq!(
        retained.decode_instance, streamed.decode_instance,
        "{ctx}: decode placement"
    );
    assert_eq!(retained.first_token, streamed.first_token, "{ctx}: first token");
    assert_eq!(
        retained.tokens_emitted(),
        streamed.tokens_emitted(),
        "{ctx}: token count"
    );
    assert_eq!(
        retained.token_times.len(),
        retained.tokens_emitted() as usize,
        "{ctx}: retained mode keeps every token time"
    );
    assert!(
        streamed.token_times.is_empty(),
        "{ctx}: streamed mode must not retain token times"
    );
    let bits = |v: Option<f64>| v.map(f64::to_bits);
    assert_eq!(bits(retained.ttft()), bits(streamed.ttft()), "{ctx}: ttft");
    assert_eq!(bits(retained.tpot()), bits(streamed.tpot()), "{ctx}: tpot");
    assert_eq!(
        bits(retained.max_token_gap()),
        bits(streamed.max_token_gap()),
        "{ctx}: max gap"
    );
}

/// Tentpole acceptance: cursor, heap-reference, and streamed runs are the
/// same simulation on every catalog workload.
#[test]
fn streamed_matches_materialized_on_every_catalog_workload() {
    let base = CostModel::normalized();
    for (name, trace, ttft_slo, tpot_slo) in catalog_traces() {
        let mk = || build(System::Arrow, 4, &base, ttft_slo, tpot_slo, false);
        let cursor = mk().run(&trace);
        let reference = mk().run_reference(&trace);
        let (streamed, streamed_recs) = run_streamed_collect(mk(), &trace);

        assert_eq!(
            cursor.events_processed, reference.events_processed,
            "{name}: cursor vs reference event counts"
        );
        assert_eq!(
            cursor.events_processed, streamed.events_processed,
            "{name}: cursor vs streamed event counts"
        );
        assert_eq!(cursor.total_iterations, streamed.total_iterations, "{name}");
        assert_eq!(cursor.sim_time.to_bits(), streamed.sim_time.to_bits(), "{name}");
        assert!(streamed.records.is_empty(), "{name}: streamed result carries no records");

        assert_eq!(cursor.records.len(), trace.len(), "{name}");
        assert_eq!(streamed_recs.len(), trace.len(), "{name}");
        for (i, (r, h)) in cursor.records.iter().zip(&reference.records).enumerate() {
            assert_eq!(r.token_times, h.token_times, "{name} req {i}: cursor vs reference");
            assert_eq!(r.state, h.state, "{name} req {i}");
        }
        for (i, (r, s)) in cursor.records.iter().zip(&streamed_recs).enumerate() {
            assert_rec_equivalent(&format!("{name} req {i}"), r, s);
        }
        // Sink receives records in arrival order (ids are normalized to
        // the arrival index).
        for (i, s) in streamed_recs.iter().enumerate() {
            assert_eq!(s.id.0 as usize, i, "{name}: sink order");
        }
    }
}

/// A lazy synthetic source drives the simulator to the same schedule as
/// the materialized trace it mirrors — no `Vec<Request>` of the whole
/// trace anywhere on the streamed path.
#[test]
fn synthetic_source_run_matches_generated_trace_run() {
    let base = CostModel::normalized();
    let w = catalog::by_name("smoke").unwrap();
    let trace = w.generate(SEED);
    let mk = || build(System::Arrow, 4, &base, w.ttft_slo, w.tpot_slo, false);

    let retained = mk().run(&trace);

    let mut src = SyntheticSource::new(&w.spec, SEED);
    let mut streamed_recs = Vec::new();
    let streamed = mk().run_streamed(&mut src, &mut |r| streamed_recs.push(r));

    assert_eq!(retained.events_processed, streamed.events_processed);
    assert_eq!(retained.total_iterations, streamed.total_iterations);
    assert_eq!(retained.records.len(), streamed_recs.len());
    for (i, (r, s)) in retained.records.iter().zip(&streamed_recs).enumerate() {
        assert_rec_equivalent(&format!("smoke req {i}"), r, s);
    }
}

/// Chaos parity: the streaming window must survive restarts, stale
/// transfer completions, and membership churn — the slot-reference
/// accounting keeps a completed-but-referenced slot resident until its
/// last in-flight transfer event resolves, so recovery sees the same
/// epochs the materialized run sees.
#[test]
fn streamed_matches_materialized_under_chaos() {
    use arrow::coordinator::arrow::{ArrowConfig, ArrowPolicy};
    let trace = arrow::trace::synthetic::smoke(150, 2).generate(15);
    let plan = FaultPlan::seeded(99, 4, trace.duration(), 1.5);
    assert!(!plan.is_empty());
    let mk = || {
        let policy = ArrowPolicy::new(ArrowConfig::new(3.0, 0.1, 4), 4);
        let cfg = SimConfig {
            transfer_retry: Some(TransferRetryPolicy::default()),
            straggler_factor: Some(3.0),
            ..Default::default()
        };
        let mut cl = Cluster::homogeneous(
            4,
            CostModel::h800_llama8b(),
            Box::new(policy),
            cfg,
        );
        cl.schedule_membership(trace.duration() * 0.5, MembershipChange::Drain(0));
        cl.schedule_fault_plan(&plan);
        cl
    };
    let retained = mk().run(&trace);
    let (streamed, streamed_recs) = run_streamed_collect(mk(), &trace);

    assert_eq!(retained.events_processed, streamed.events_processed, "chaos event counts");
    assert_eq!(retained.records.len(), streamed_recs.len());
    for (i, (r, s)) in retained.records.iter().zip(&streamed_recs).enumerate() {
        assert_rec_equivalent(&format!("chaos req {i}"), r, s);
        // No-silent-loss carries over to the streamed path.
        assert!(s.finished() || s.shed.is_some(), "chaos req {i} silently lost");
    }
}

/// The constant-memory SLO sink agrees with the exact oracle: counting
/// fields bit-identical, sketched percentiles within the documented band.
#[test]
fn streaming_slo_sink_matches_from_records_oracle() {
    let base = CostModel::normalized();
    for (name, trace, ttft_slo, tpot_slo) in catalog_traces() {
        let mk = || build(System::Arrow, 4, &base, ttft_slo, tpot_slo, false);
        let span = trace.duration();

        let retained = mk().run(&trace);
        let exact = SloReport::from_records(&retained.records, ttft_slo, tpot_slo, span);

        let mut slo = StreamingSlo::new(ttft_slo, tpot_slo);
        let mut src = TraceSource::new(&trace);
        mk().run_streamed(&mut src, &mut |r| slo.observe(&r));
        let est = slo.report(span);

        assert_eq!(exact.n_requests, est.n_requests, "{name}");
        assert_eq!(exact.n_finished, est.n_finished, "{name}");
        assert_eq!(exact.n_failed, est.n_failed, "{name}");
        assert_eq!(
            exact.slo_attainment.to_bits(),
            est.slo_attainment.to_bits(),
            "{name}: attainment is exact in streaming mode"
        );
        assert_eq!(
            exact.token_throughput.to_bits(),
            est.token_throughput.to_bits(),
            "{name}: throughput is exact in streaming mode"
        );
        assert_eq!(
            exact.goodput_tokens.to_bits(),
            est.goodput_tokens.to_bits(),
            "{name}: goodput is exact in streaming mode"
        );
        // Sketched percentiles: inside a 10% relative band of the exact
        // oracle (absolute floor for near-zero latencies).
        let close = |a: f64, b: f64| {
            (a.is_nan() && b.is_nan()) || (a - b).abs() <= 0.10 * b.abs().max(1e-3)
        };
        for (ex, es, what) in [
            (exact.p50_ttft, est.p50_ttft, "p50_ttft"),
            (exact.p90_ttft, est.p90_ttft, "p90_ttft"),
            (exact.p99_ttft, est.p99_ttft, "p99_ttft"),
            (exact.p50_tpot, est.p50_tpot, "p50_tpot"),
            (exact.p90_tpot, est.p90_tpot, "p90_tpot"),
            (exact.p99_tpot, est.p99_tpot, "p99_tpot"),
        ] {
            assert!(
                close(es, ex),
                "{name} {what}: sketch {es} vs exact {ex} outside band"
            );
        }
    }
}
