//! Moments-vs-walk conformance property test (PR 4 tentpole lock).
//!
//! The placement hot path prices queue delay from incrementally
//! maintained integer moments (`PrefillQueueMoments`) instead of walking
//! the queue. This property test drives arbitrary interleavings of
//! enqueue / chunked progress / completion / membership churn and
//! asserts, after every op and in lockstep through BOTH adapters
//! (`sim::SimView` over the live instance table, and a scripted
//! `server::view::ServerView` maintained with the coordinator's update
//! rules):
//!
//! 1. **Exact aggregates** — the incrementally maintained moments equal
//!    the walk-derived moments bit-for-bit (integer path independence).
//! 2. **Delay equivalence** — `queue_delay_moments` equals the
//!    `queue_delay_view` walk within 1e-9 relative.
//! 3. **Cross-substrate identity** — the scripted coordinator's
//!    *independently maintained* moments price bit-identically to the
//!    sim's (both served through a real `ServerView` snapshot), so the
//!    two substrates key placements identically despite never sharing
//!    state.

use arrow::coordinator::predictor::TtftPredictor;
use arrow::costmodel::CostModel;
use arrow::engine::{Produced, SimInstance};
use arrow::prop_assert;
use arrow::request::{InstanceId, RequestId};
use arrow::sched::{ClusterView, Liveness, PrefillQueueMoments, EPOCH_UNKNOWN};
use arrow::server::view::{EngineSnapshot, ServerView};
use arrow::sim::SimView;
use arrow::util::{prop, rng::Rng};

/// A scripted coordinator: maintains per-instance moments with the same
/// incremental rules the live server uses (add on dispatch, advance on
/// observed chunk progress, pop on completion, reset on failure) —
/// *without* ever walking the queue.
struct ScriptedCoordinator {
    moments: Vec<PrefillQueueMoments>,
    /// (input_len, remaining) ledger, only consulted to know the head's
    /// remaining at advance time (the live analog: PrefillDone events).
    ledger: Vec<Vec<(u32, u32)>>,
    chunk: u32,
}

impl ScriptedCoordinator {
    fn new(n: usize, chunk: u32) -> Self {
        ScriptedCoordinator {
            moments: vec![PrefillQueueMoments::default(); n],
            ledger: vec![Vec::new(); n],
            chunk,
        }
    }

    fn dispatch(&mut self, i: usize, len: u32) {
        self.moments[i].add_task(len, len, self.chunk);
        self.ledger[i].push((len, len));
    }

    fn advance_head(&mut self, i: usize, chunk: u32) {
        let (len, rem) = self.ledger[i][0];
        let new_rem = rem - chunk.min(rem);
        self.moments[i].advance_head(len, rem, new_rem, self.chunk);
        self.ledger[i][0].1 = new_rem;
    }

    fn pop_head(&mut self, i: usize) {
        let (_, rem) = self.ledger[i].remove(0);
        assert_eq!(rem, 0, "head popped before it finished");
        self.moments[i].pop_finished_head();
    }

    fn fail(&mut self, i: usize) {
        self.moments[i] = PrefillQueueMoments::default();
        self.ledger[i].clear();
    }

    /// Materialize the live-server snapshot this coordinator would build
    /// — its OWN moments, never copied from the sim side, so the
    /// cross-substrate comparison exercises an independent update
    /// history.
    fn view(&self) -> ServerView {
        ServerView {
            engines: (0..self.moments.len())
                .map(|i| EngineSnapshot {
                    queued_prefills: self.ledger[i].clone(),
                    moments: self.moments[i],
                    chunk_tokens: self.chunk,
                    running_tokens: 0,
                    max_kv_tokens: u64::MAX,
                    avg_token_interval: f64::NAN,
                    has_decode_work: false,
                    liveness: Liveness::Active,
                })
                .collect(),
            change_epoch: EPOCH_UNKNOWN,
        }
    }
}

/// The production coordinator's actual rule set is different from the
/// sim's: it never observes chunk progress — only `add_task` at
/// dispatch, `remove_task(len, len)` at PrefillDone/Failed (from any
/// queue position), and a full reset on engine failure. Drive exactly
/// those ops under churn and assert the moments always equal a fresh
/// derivation from the ledger (and price within tolerance of the walk).
#[test]
fn prop_server_dequeue_rules_keep_moments_exact() {
    prop::check_with(173, 64, |rng: &mut Rng| {
        let cost = CostModel::h800_llama8b();
        let chunk = 2048u32;
        let pred = TtftPredictor::profile(&cost, chunk);
        let mut moments = PrefillQueueMoments::default();
        let mut ledger: Vec<(u32, u32)> = Vec::new();
        for step in 0..80u64 {
            match rng.index(5) {
                0 | 1 | 2 => {
                    let len = rng.int_range(64, 50_000) as u32;
                    moments.add_task(len, len, chunk);
                    ledger.push((len, len));
                }
                3 if !ledger.is_empty() => {
                    // PrefillDone / Failed can complete ANY dispatched
                    // request, not just the head (engines finish out of
                    // coordinator-queue order under continuous batching).
                    let pos = rng.index(ledger.len());
                    let (len, rem) = ledger.remove(pos);
                    moments.remove_task(len, rem, chunk);
                }
                4 => {
                    // Engine failure: the whole queue re-dispatches.
                    moments = PrefillQueueMoments::default();
                    ledger.clear();
                }
                _ => {}
            }
            let mut derived = PrefillQueueMoments::default();
            for &(l, r) in &ledger {
                derived.add_task(l, r, chunk);
            }
            prop_assert!(
                moments == derived,
                "step {step}: maintained {moments:?} != derived {derived:?}"
            );
            let via_moments = pred.queue_delay_moments(&moments);
            let via_walk = pred.queue_delay_iter(ledger.iter().copied());
            let tol = 1e-9 * via_walk.abs().max(1.0);
            prop_assert!(
                (via_moments - via_walk).abs() <= tol,
                "step {step}: {via_moments} vs walk {via_walk}"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_incremental_moments_equal_walk_under_churn() {
    prop::check_with(131, 48, |rng: &mut Rng| {
        let n = rng.index(4) + 2; // 2..=5 instances
        let cost = CostModel::h800_llama8b();
        let mut insts: Vec<SimInstance> = (0..n)
            .map(|i| SimInstance::new(InstanceId(i), cost.clone()))
            .collect();
        let chunk = insts[0].chunk_tokens;
        let preds: Vec<TtftPredictor> = insts
            .iter()
            .map(|i| TtftPredictor::profile(&i.cost, i.chunk_tokens))
            .collect();
        let mut coord = ScriptedCoordinator::new(n, chunk);
        let mut next = 0u64;

        for step in 0..60u64 {
            let i = rng.index(n);
            match rng.index(4) {
                0 | 1 => {
                    // Enqueue a prefill on both substrates.
                    let len = rng.int_range(64, 40_000) as u32;
                    insts[i].enqueue_prefill(RequestId(next), len);
                    coord.dispatch(i, len);
                    next += 1;
                }
                2 => {
                    // One engine iteration: the sim advances its head
                    // chunk; the scripted coordinator applies the same
                    // observed progress (chunk size + completion event).
                    if let Some(plan) = insts[i].plan_iteration() {
                        if plan.chunk > 0 {
                            coord.advance_head(i, plan.chunk);
                        }
                        for p in insts[i].finish_iteration(&plan, step as f64) {
                            if let Produced::PrefillDone { kv_tokens, .. } = p {
                                coord.pop_head(i);
                                insts[i].migration_out_done(kv_tokens);
                            }
                        }
                    }
                }
                _ => {
                    // Membership churn: the instance fails and loses its
                    // queue on both substrates.
                    let mut scrap = Vec::new();
                    insts[i].drain_request_ids(&mut scrap);
                    coord.fail(i);
                }
            }

            // 1. Incremental == walk-derived, exactly, on every slot.
            let sim_view = SimView(&insts);
            for j in 0..n {
                let inc = sim_view.prefill_queue_moments(j);
                let walk = PrefillQueueMoments::derive_walk(&sim_view, j);
                prop_assert!(
                    inc == walk,
                    "step {step} inst {j}: sim moments {inc:?} != walk {walk:?}"
                );
                prop_assert!(
                    coord.moments[j] == walk,
                    "step {step} inst {j}: scripted moments {:?} != walk {walk:?}",
                    coord.moments[j]
                );
            }

            // 2./3. Delay equivalence, and cross-substrate bit identity
            // against the coordinator's INDEPENDENT bookkeeping served
            // through a real ServerView snapshot.
            let srv_view = coord.view();
            for j in 0..n {
                let via_walk = preds[j].queue_delay_view(&sim_view, j);
                let via_moments = preds[j].queue_delay_moments(&sim_view.prefill_queue_moments(j));
                let tol = 1e-9 * via_walk.abs().max(1.0);
                prop_assert!(
                    (via_moments - via_walk).abs() <= tol,
                    "step {step} inst {j}: moments {via_moments} vs walk {via_walk}"
                );
                let via_server =
                    preds[j].queue_delay_moments(&srv_view.prefill_queue_moments(j));
                prop_assert!(
                    via_server.to_bits() == via_moments.to_bits(),
                    "step {step} inst {j}: substrates disagree ({via_server} vs {via_moments})"
                );
            }
        }
        Ok(())
    });
}
