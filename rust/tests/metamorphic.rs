//! Metamorphic conformance tier (PR 5): scheduler-level invariants that
//! no cost-model calibration can break. Where `tests/claims.rs` checks
//! the paper's *orderings*, this file checks *relations between runs*:
//!
//! * rate-scaling monotonicity — pushing more load never raises SLO
//!   attainment;
//! * trace-permutation determinism — equal-time, equal-shape arrivals
//!   are interchangeable, and tie-heavy traces schedule identically in
//!   the cursor and heap-reference event loops;
//! * cost-scale invariance — dilating every time dimension (cost model,
//!   arrivals, SLOs, monitor period) by a power of two reproduces the
//!   *identical* placement schedule, bit for bit: scheduling decisions
//!   depend only on ratios of times, so a divergence means a placement
//!   path sneaked in an absolute-seconds constant;
//! * elastic-membership dominance — more instances never lower the
//!   maximum sustainable rate, and spare instances joining mid-burst
//!   never hurt attainment.
//!
//! Everything runs under [`CostModel::normalized`] (the conformance
//! contract: these properties must hold on every commit, on every
//! machine, with no calibration step).

use arrow::costmodel::CostModel;
use arrow::metrics::{max_sustainable_rate, SloReport};
use arrow::request::Request;
use arrow::scenarios::{build, build_time_scaled, spike_scale_out, System};
use arrow::sim::SimResult;
use arrow::trace::{catalog, Trace};
use arrow::util::rng::Rng;

fn report(res: &SimResult, ttft: f64, tpot: f64, span: f64) -> SloReport {
    SloReport::from_records(&res.records, ttft, tpot, span)
}

// ---------------------------------------------------------------------------
// Rate-scaling monotonicity
// ---------------------------------------------------------------------------

#[test]
fn slo_attainment_never_rises_with_load() {
    let w = catalog::by_name("azure_code").unwrap();
    let trace = w.generate(6).clip_seconds(180.0);
    let base_rate = trace.rate();
    let base = CostModel::normalized();
    for sys in [System::Arrow, System::MinimalLoad, System::VllmColocated] {
        let mut last = f64::INFINITY;
        for mult in [1.0, 6.0, 24.0] {
            let t = trace.with_rate(base_rate * mult);
            let cl = build(sys, 8, &base, w.ttft_slo, w.tpot_slo, false);
            let rep = report(&cl.run(&t), w.ttft_slo, w.tpot_slo, t.duration());
            // Small tolerance: rescaling compresses the burst structure,
            // which can realign a handful of requests across the SLO
            // boundary — but attainment must never *rise* with load.
            assert!(
                rep.slo_attainment <= last + 0.05,
                "{}: attainment rose with load at x{mult}: {last:.3} -> {:.3}",
                sys.label(),
                rep.slo_attainment
            );
            last = rep.slo_attainment;
        }
    }
}

// ---------------------------------------------------------------------------
// Trace-permutation determinism of equal-time arrivals
// ---------------------------------------------------------------------------

/// 24 tie groups of 5 requests each: every member of a group shares the
/// exact arrival timestamp *and* shape, so any permutation of the input
/// list is the same workload.
fn tie_trace() -> (Vec<Request>, Rng) {
    let mut rng = Rng::new(77);
    let mut reqs = Vec::new();
    let mut id = 0u64;
    for g in 0..24 {
        let at = g as f64 * 1.25;
        let input = rng.int_range(64, 4096) as u32;
        let output = rng.int_range(4, 64) as u32;
        for _ in 0..5 {
            reqs.push(Request::new(id, at, input, output));
            id += 1;
        }
    }
    (reqs, rng)
}

#[test]
fn equal_time_equal_shape_arrivals_are_order_invariant() {
    let (reqs, mut rng) = tie_trace();
    let forward = Trace::new("ties", reqs.clone());
    let mut shuffled = reqs;
    rng.shuffle(&mut shuffled);
    let permuted = Trace::new("ties", shuffled);
    let base = CostModel::normalized();
    for sys in [System::Arrow, System::MinimalLoad, System::RoundRobin] {
        let a = build(sys, 8, &base, 2.0, 0.1, false).run(&forward);
        let b = build(sys, 8, &base, 2.0, 0.1, false).run(&permuted);
        assert_eq!(a.records.len(), b.records.len());
        for (ra, rb) in a.records.iter().zip(&b.records) {
            assert_eq!(
                ra.prefill_instance, rb.prefill_instance,
                "{}: tie permutation moved a prefill placement",
                sys.label()
            );
            assert_eq!(ra.decode_instance, rb.decode_instance, "{}", sys.label());
            assert_eq!(ra.state, rb.state, "{}", sys.label());
            assert_eq!(ra.token_times.len(), rb.token_times.len());
            for (ta, tb) in ra.token_times.iter().zip(&rb.token_times) {
                assert_eq!(
                    ta.to_bits(),
                    tb.to_bits(),
                    "{}: token time drifted under tie permutation",
                    sys.label()
                );
            }
        }
        assert_eq!(a.total_flips, b.total_flips, "{}", sys.label());
        assert_eq!(a.total_iterations, b.total_iterations, "{}", sys.label());
    }
}

#[test]
fn tie_heavy_trace_schedules_identically_in_cursor_and_heap_modes() {
    // The (time, seq) total order must break exact arrival ties the same
    // way whether arrivals come from the calendar cursor or were
    // pre-pushed into the heap (PR-1 equivalence contract, stressed with
    // maximal tie density).
    let (reqs, _) = tie_trace();
    let trace = Trace::new("ties", reqs);
    let base = CostModel::normalized();
    for sys in [System::Arrow, System::MinimalLoad] {
        let cur = build(sys, 8, &base, 2.0, 0.1, false).run(&trace);
        let heap = build(sys, 8, &base, 2.0, 0.1, false).run_reference(&trace);
        assert_eq!(cur.events_processed, heap.events_processed, "{}", sys.label());
        for (rc, rh) in cur.records.iter().zip(&heap.records) {
            assert_eq!(rc.prefill_instance, rh.prefill_instance, "{}", sys.label());
            assert_eq!(rc.decode_instance, rh.decode_instance, "{}", sys.label());
            for (tc, th) in rc.token_times.iter().zip(&rh.token_times) {
                assert_eq!(tc.to_bits(), th.to_bits(), "{}", sys.label());
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Cost-scale invariance of placement decisions
// ---------------------------------------------------------------------------

/// Dilate arrivals by exactly `k` (power of two => bit-exact).
fn scale_trace(t: &Trace, k: f64) -> Trace {
    Trace::new(
        &t.name,
        t.requests
            .iter()
            .map(|r| Request {
                arrival: r.arrival * k,
                ..*r
            })
            .collect(),
    )
}

#[test]
fn scaling_all_times_by_k_changes_no_placement() {
    // Loaded enough that queues, transfers, and (for Arrow) flips are
    // all in play — invariance on an idle trace would prove nothing.
    let w = catalog::by_name("azure_code").unwrap();
    let trace = {
        let t = w.generate(11).clip_seconds(60.0);
        let r = t.rate();
        t.with_rate(r * 8.0)
    };
    let base = CostModel::normalized();
    for sys in System::all() {
        let a = build(sys, 8, &base, w.ttft_slo, w.tpot_slo, false).run(&trace);
        // Sanity: the regime is non-trivial for every system.
        assert!(
            a.records.iter().any(|r| r.finished()),
            "{}: nothing finished, dilation check is vacuous",
            sys.label()
        );
        for &k in &[2.0, 0.5] {
            let st = scale_trace(&trace, k);
            let b = build_time_scaled(sys, 8, &base, w.ttft_slo, w.tpot_slo, false, k).run(&st);
            assert_eq!(a.records.len(), b.records.len());
            for (ra, rb) in a.records.iter().zip(&b.records) {
                assert_eq!(
                    ra.prefill_instance, rb.prefill_instance,
                    "{}/k={k}: prefill placement moved under pure time dilation \
                     (an absolute-seconds constant leaked into a placement path)",
                    sys.label()
                );
                assert_eq!(
                    ra.decode_instance, rb.decode_instance,
                    "{}/k={k}: decode placement moved under pure time dilation",
                    sys.label()
                );
                assert_eq!(ra.state, rb.state, "{}/k={k}", sys.label());
                assert_eq!(ra.token_times.len(), rb.token_times.len());
                for (ta, tb) in ra.token_times.iter().zip(&rb.token_times) {
                    assert_eq!(
                        (ta * k).to_bits(),
                        tb.to_bits(),
                        "{}/k={k}: token timestamp not an exact dilation",
                        sys.label()
                    );
                }
            }
            assert_eq!(a.total_flips, b.total_flips, "{}/k={k}: flip count", sys.label());
            assert_eq!(
                a.total_iterations, b.total_iterations,
                "{}/k={k}: iteration count",
                sys.label()
            );
            assert_eq!(
                a.events_processed, b.events_processed,
                "{}/k={k}: event count",
                sys.label()
            );
        }
    }
}

#[test]
fn scaled_run_preserves_slo_attainment_exactly() {
    // The metric layer sees dilated latencies against dilated SLOs: the
    // attainment fraction must be *identical*, not merely close.
    let w = catalog::by_name("azure_code").unwrap();
    let trace = {
        let t = w.generate(11).clip_seconds(60.0);
        let r = t.rate();
        t.with_rate(r * 8.0)
    };
    let base = CostModel::normalized();
    let k = 2.0;
    for sys in [System::Arrow, System::MinimalLoad] {
        let a = build(sys, 8, &base, w.ttft_slo, w.tpot_slo, false).run(&trace);
        let st = scale_trace(&trace, k);
        let b = build_time_scaled(sys, 8, &base, w.ttft_slo, w.tpot_slo, false, k).run(&st);
        let ra = report(&a, w.ttft_slo, w.tpot_slo, trace.duration());
        let rb = report(&b, w.ttft_slo * k, w.tpot_slo * k, st.duration());
        assert_eq!(ra.n_finished, rb.n_finished, "{}", sys.label());
        assert_eq!(ra.n_failed, rb.n_failed, "{}", sys.label());
        assert_eq!(
            ra.slo_attainment.to_bits(),
            rb.slo_attainment.to_bits(),
            "{}: attainment must be exactly dilation-invariant",
            sys.label()
        );
    }
}

// ---------------------------------------------------------------------------
// Elastic-membership dominance
// ---------------------------------------------------------------------------

#[test]
fn more_instances_never_lower_max_sustainable_rate() {
    let w = catalog::by_name("azure_code").unwrap();
    let trace = w.generate(9).clip_seconds(120.0);
    let base_rate = trace.rate();
    let base = CostModel::normalized();
    let max_rate = |gpus: usize| {
        max_sustainable_rate(
            |rate| {
                let t = trace.with_rate(rate);
                let cl = build(System::Arrow, gpus, &base, w.ttft_slo, w.tpot_slo, false);
                report(&cl.run(&t), w.ttft_slo, w.tpot_slo, t.duration())
            },
            base_rate,
            0.9,
            0.1,
        )
    };
    let r4 = max_rate(4);
    let r6 = max_rate(6);
    let r8 = max_rate(8);
    assert!(r4 > 0.0, "4 instances must sustain the base rate regime");
    // Band absorbs the bisection quantization (10% tolerance), nothing
    // else: capacity must be monotone in the instance count.
    assert!(r6 >= r4 * 0.85, "6 GPUs sustain {r6:.2} < 4 GPUs {r4:.2}");
    assert!(r8 >= r6 * 0.85, "8 GPUs sustain {r8:.2} < 6 GPUs {r6:.2}");
    assert!(r8 >= r4 * 0.9, "8 GPUs sustain {r8:.2} vs 4 GPUs {r4:.2}");
}

#[test]
fn spare_instances_joining_mid_run_never_hurt() {
    // Elastic dominance, membership flavor: a 4-instance cluster that
    // scales out to 8 mid-burst must do at least as well as the fixed
    // 4-instance cluster on the same overloaded trace.
    let w = catalog::by_name("azure_code").unwrap();
    let trace = {
        let t = w.generate(9).clip_seconds(120.0);
        let r = t.rate();
        t.with_rate(r * 10.0)
    };
    let base = CostModel::normalized();
    let fixed = build(System::Arrow, 4, &base, w.ttft_slo, w.tpot_slo, false).run(&trace);
    let elastic =
        spike_scale_out(4, 4, &base, w.ttft_slo, w.tpot_slo, 0.25 * trace.duration()).run(&trace);
    let rf = report(&fixed, w.ttft_slo, w.tpot_slo, trace.duration());
    let re = report(&elastic, w.ttft_slo, w.tpot_slo, trace.duration());
    assert_eq!(re.n_finished + re.n_failed, re.n_requests);
    assert!(
        re.slo_attainment >= rf.slo_attainment - 0.02,
        "scale-out attainment {:.3} fell below fixed-membership {:.3}",
        re.slo_attainment,
        rf.slo_attainment
    );
    assert!(
        re.goodput_tokens >= rf.goodput_tokens * 0.98,
        "scale-out goodput {:.1} fell below fixed-membership {:.1}",
        re.goodput_tokens,
        rf.goodput_tokens
    );
}
