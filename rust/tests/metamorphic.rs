//! Metamorphic conformance tier (PR 5): scheduler-level invariants that
//! no cost-model calibration can break. Where `tests/claims.rs` checks
//! the paper's *orderings*, this file checks *relations between runs*:
//!
//! * rate-scaling monotonicity — pushing more load never raises SLO
//!   attainment;
//! * trace-permutation determinism — equal-time, equal-shape arrivals
//!   are interchangeable, and tie-heavy traces schedule identically in
//!   the cursor and heap-reference event loops;
//! * cost-scale invariance — dilating every time dimension (cost model,
//!   arrivals, SLOs, monitor period) by a power of two reproduces the
//!   *identical* placement schedule, bit for bit: scheduling decisions
//!   depend only on ratios of times, so a divergence means a placement
//!   path sneaked in an absolute-seconds constant;
//! * elastic-membership dominance — more instances never lower the
//!   maximum sustainable rate, and spare instances joining mid-burst
//!   never hurt attainment.
//!
//! Everything runs under [`CostModel::normalized`] (the conformance
//! contract: these properties must hold on every commit, on every
//! machine, with no calibration step).

use arrow::costmodel::CostModel;
use arrow::metrics::{max_sustainable_rate, SloReport};
use arrow::request::{Request, SloClass};
use arrow::scenarios::{
    build, build_arrow_classed, build_time_scaled, spike_scale_out, spike_scale_out_for, System,
};
use arrow::sim::{AdmissionControl, SimResult};
use arrow::trace::{catalog, Trace};
use arrow::util::rng::Rng;

fn report(res: &SimResult, ttft: f64, tpot: f64, span: f64) -> SloReport {
    SloReport::from_records(&res.records, ttft, tpot, span)
}

// ---------------------------------------------------------------------------
// Rate-scaling monotonicity
// ---------------------------------------------------------------------------

#[test]
fn slo_attainment_never_rises_with_load() {
    let w = catalog::by_name("azure_code").unwrap();
    let trace = w.generate(6).clip_seconds(180.0);
    let base_rate = trace.rate();
    let base = CostModel::normalized();
    for sys in [System::Arrow, System::MinimalLoad, System::VllmColocated] {
        let mut last = f64::INFINITY;
        for mult in [1.0, 6.0, 24.0] {
            let t = trace.with_rate(base_rate * mult);
            let cl = build(sys, 8, &base, w.ttft_slo, w.tpot_slo, false);
            let rep = report(&cl.run(&t), w.ttft_slo, w.tpot_slo, t.duration());
            // Small tolerance: rescaling compresses the burst structure,
            // which can realign a handful of requests across the SLO
            // boundary — but attainment must never *rise* with load.
            assert!(
                rep.slo_attainment <= last + 0.05,
                "{}: attainment rose with load at x{mult}: {last:.3} -> {:.3}",
                sys.label(),
                rep.slo_attainment
            );
            last = rep.slo_attainment;
        }
    }
}

// ---------------------------------------------------------------------------
// Trace-permutation determinism of equal-time arrivals
// ---------------------------------------------------------------------------

/// 24 tie groups of 5 requests each: every member of a group shares the
/// exact arrival timestamp *and* shape, so any permutation of the input
/// list is the same workload.
fn tie_trace() -> (Vec<Request>, Rng) {
    let mut rng = Rng::new(77);
    let mut reqs = Vec::new();
    let mut id = 0u64;
    for g in 0..24 {
        let at = g as f64 * 1.25;
        let input = rng.int_range(64, 4096) as u32;
        let output = rng.int_range(4, 64) as u32;
        for _ in 0..5 {
            reqs.push(Request::new(id, at, input, output));
            id += 1;
        }
    }
    (reqs, rng)
}

#[test]
fn equal_time_equal_shape_arrivals_are_order_invariant() {
    let (reqs, mut rng) = tie_trace();
    let forward = Trace::new("ties", reqs.clone());
    let mut shuffled = reqs;
    rng.shuffle(&mut shuffled);
    let permuted = Trace::new("ties", shuffled);
    let base = CostModel::normalized();
    for sys in [
        System::Arrow,
        System::MinimalLoad,
        System::RoundRobin,
        System::Deflect,
        System::Unified,
    ] {
        let a = build(sys, 8, &base, 2.0, 0.1, false).run(&forward);
        let b = build(sys, 8, &base, 2.0, 0.1, false).run(&permuted);
        assert_eq!(a.records.len(), b.records.len());
        for (ra, rb) in a.records.iter().zip(&b.records) {
            assert_eq!(
                ra.prefill_instance, rb.prefill_instance,
                "{}: tie permutation moved a prefill placement",
                sys.label()
            );
            assert_eq!(ra.decode_instance, rb.decode_instance, "{}", sys.label());
            assert_eq!(ra.state, rb.state, "{}", sys.label());
            assert_eq!(ra.token_times.len(), rb.token_times.len());
            for (ta, tb) in ra.token_times.iter().zip(&rb.token_times) {
                assert_eq!(
                    ta.to_bits(),
                    tb.to_bits(),
                    "{}: token time drifted under tie permutation",
                    sys.label()
                );
            }
        }
        assert_eq!(a.total_flips, b.total_flips, "{}", sys.label());
        assert_eq!(a.total_iterations, b.total_iterations, "{}", sys.label());
    }
}

#[test]
fn tie_heavy_trace_schedules_identically_in_cursor_and_heap_modes() {
    // The (time, seq) total order must break exact arrival ties the same
    // way whether arrivals come from the calendar cursor or were
    // pre-pushed into the heap (PR-1 equivalence contract, stressed with
    // maximal tie density).
    let (reqs, _) = tie_trace();
    let trace = Trace::new("ties", reqs);
    let base = CostModel::normalized();
    for sys in [System::Arrow, System::MinimalLoad, System::Deflect, System::Unified] {
        let cur = build(sys, 8, &base, 2.0, 0.1, false).run(&trace);
        let heap = build(sys, 8, &base, 2.0, 0.1, false).run_reference(&trace);
        assert_eq!(cur.events_processed, heap.events_processed, "{}", sys.label());
        for (rc, rh) in cur.records.iter().zip(&heap.records) {
            assert_eq!(rc.prefill_instance, rh.prefill_instance, "{}", sys.label());
            assert_eq!(rc.decode_instance, rh.decode_instance, "{}", sys.label());
            for (tc, th) in rc.token_times.iter().zip(&rh.token_times) {
                assert_eq!(tc.to_bits(), th.to_bits(), "{}", sys.label());
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Cost-scale invariance of placement decisions
// ---------------------------------------------------------------------------

/// Dilate arrivals by exactly `k` (power of two => bit-exact).
fn scale_trace(t: &Trace, k: f64) -> Trace {
    Trace::new(
        &t.name,
        t.requests
            .iter()
            .map(|r| Request {
                arrival: r.arrival * k,
                ..*r
            })
            .collect(),
    )
}

#[test]
fn scaling_all_times_by_k_changes_no_placement() {
    // Loaded enough that queues, transfers, and (for Arrow) flips are
    // all in play — invariance on an idle trace would prove nothing.
    let w = catalog::by_name("azure_code").unwrap();
    let trace = {
        let t = w.generate(11).clip_seconds(60.0);
        let r = t.rate();
        t.with_rate(r * 8.0)
    };
    let base = CostModel::normalized();
    for sys in System::all() {
        let a = build(sys, 8, &base, w.ttft_slo, w.tpot_slo, false).run(&trace);
        // Sanity: the regime is non-trivial for every system.
        assert!(
            a.records.iter().any(|r| r.finished()),
            "{}: nothing finished, dilation check is vacuous",
            sys.label()
        );
        for &k in &[2.0, 0.5] {
            let st = scale_trace(&trace, k);
            let b = build_time_scaled(sys, 8, &base, w.ttft_slo, w.tpot_slo, false, k).run(&st);
            assert_eq!(a.records.len(), b.records.len());
            for (ra, rb) in a.records.iter().zip(&b.records) {
                assert_eq!(
                    ra.prefill_instance, rb.prefill_instance,
                    "{}/k={k}: prefill placement moved under pure time dilation \
                     (an absolute-seconds constant leaked into a placement path)",
                    sys.label()
                );
                assert_eq!(
                    ra.decode_instance, rb.decode_instance,
                    "{}/k={k}: decode placement moved under pure time dilation",
                    sys.label()
                );
                assert_eq!(ra.state, rb.state, "{}/k={k}", sys.label());
                assert_eq!(ra.token_times.len(), rb.token_times.len());
                for (ta, tb) in ra.token_times.iter().zip(&rb.token_times) {
                    assert_eq!(
                        (ta * k).to_bits(),
                        tb.to_bits(),
                        "{}/k={k}: token timestamp not an exact dilation",
                        sys.label()
                    );
                }
            }
            assert_eq!(a.total_flips, b.total_flips, "{}/k={k}: flip count", sys.label());
            assert_eq!(
                a.total_iterations, b.total_iterations,
                "{}/k={k}: iteration count",
                sys.label()
            );
            assert_eq!(
                a.events_processed, b.events_processed,
                "{}/k={k}: event count",
                sys.label()
            );
        }
    }
}

#[test]
fn scaled_run_preserves_slo_attainment_exactly() {
    // The metric layer sees dilated latencies against dilated SLOs: the
    // attainment fraction must be *identical*, not merely close.
    let w = catalog::by_name("azure_code").unwrap();
    let trace = {
        let t = w.generate(11).clip_seconds(60.0);
        let r = t.rate();
        t.with_rate(r * 8.0)
    };
    let base = CostModel::normalized();
    let k = 2.0;
    for sys in [System::Arrow, System::MinimalLoad] {
        let a = build(sys, 8, &base, w.ttft_slo, w.tpot_slo, false).run(&trace);
        let st = scale_trace(&trace, k);
        let b = build_time_scaled(sys, 8, &base, w.ttft_slo, w.tpot_slo, false, k).run(&st);
        let ra = report(&a, w.ttft_slo, w.tpot_slo, trace.duration());
        let rb = report(&b, w.ttft_slo * k, w.tpot_slo * k, st.duration());
        assert_eq!(ra.n_finished, rb.n_finished, "{}", sys.label());
        assert_eq!(ra.n_failed, rb.n_failed, "{}", sys.label());
        assert_eq!(
            ra.slo_attainment.to_bits(),
            rb.slo_attainment.to_bits(),
            "{}: attainment must be exactly dilation-invariant",
            sys.label()
        );
    }
}

// ---------------------------------------------------------------------------
// Elastic-membership dominance
// ---------------------------------------------------------------------------

#[test]
fn more_instances_never_lower_max_sustainable_rate() {
    let w = catalog::by_name("azure_code").unwrap();
    let trace = w.generate(9).clip_seconds(120.0);
    let base_rate = trace.rate();
    let base = CostModel::normalized();
    let max_rate = |gpus: usize| {
        max_sustainable_rate(
            |rate| {
                let t = trace.with_rate(rate);
                let cl = build(System::Arrow, gpus, &base, w.ttft_slo, w.tpot_slo, false);
                report(&cl.run(&t), w.ttft_slo, w.tpot_slo, t.duration())
            },
            base_rate,
            0.9,
            0.1,
        )
    };
    let r4 = max_rate(4);
    let r6 = max_rate(6);
    let r8 = max_rate(8);
    assert!(r4 > 0.0, "4 instances must sustain the base rate regime");
    // Band absorbs the bisection quantization (10% tolerance), nothing
    // else: capacity must be monotone in the instance count.
    assert!(r6 >= r4 * 0.85, "6 GPUs sustain {r6:.2} < 4 GPUs {r4:.2}");
    assert!(r8 >= r6 * 0.85, "8 GPUs sustain {r8:.2} < 6 GPUs {r6:.2}");
    assert!(r8 >= r4 * 0.9, "8 GPUs sustain {r8:.2} vs 4 GPUs {r4:.2}");
}

#[test]
fn spare_instances_joining_mid_run_never_hurt() {
    // Elastic dominance, membership flavor: a 4-instance cluster that
    // scales out to 8 mid-burst must do at least as well as the fixed
    // 4-instance cluster on the same overloaded trace.
    let w = catalog::by_name("azure_code").unwrap();
    let trace = {
        let t = w.generate(9).clip_seconds(120.0);
        let r = t.rate();
        t.with_rate(r * 10.0)
    };
    let base = CostModel::normalized();
    let fixed = build(System::Arrow, 4, &base, w.ttft_slo, w.tpot_slo, false).run(&trace);
    let elastic =
        spike_scale_out(4, 4, &base, w.ttft_slo, w.tpot_slo, 0.25 * trace.duration()).run(&trace);
    let rf = report(&fixed, w.ttft_slo, w.tpot_slo, trace.duration());
    let re = report(&elastic, w.ttft_slo, w.tpot_slo, trace.duration());
    assert_eq!(re.n_finished + re.n_failed, re.n_requests);
    assert!(
        re.slo_attainment >= rf.slo_attainment - 0.02,
        "scale-out attainment {:.3} fell below fixed-membership {:.3}",
        re.slo_attainment,
        rf.slo_attainment
    );
    assert!(
        re.goodput_tokens >= rf.goodput_tokens * 0.98,
        "scale-out goodput {:.1} fell below fixed-membership {:.1}",
        re.goodput_tokens,
        rf.goodput_tokens
    );
}

#[test]
fn spare_instances_never_hurt_the_scheduling_adversaries_either() {
    // PR 10: the elastic-membership dominance property extends to both
    // new adversaries — deflection (whose inner Arrow re-seeds pools on
    // joins) and the unified design (where a joiner simply takes the one
    // slot every member occupies).
    let w = catalog::by_name("azure_code").unwrap();
    let trace = {
        let t = w.generate(9).clip_seconds(120.0);
        let r = t.rate();
        t.with_rate(r * 10.0)
    };
    let base = CostModel::normalized();
    for sys in [System::Deflect, System::Unified] {
        let fixed = build(sys, 4, &base, w.ttft_slo, w.tpot_slo, false).run(&trace);
        let elastic = spike_scale_out_for(
            sys,
            4,
            4,
            &base,
            w.ttft_slo,
            w.tpot_slo,
            0.25 * trace.duration(),
        )
        .run(&trace);
        let rf = report(&fixed, w.ttft_slo, w.tpot_slo, trace.duration());
        let re = report(&elastic, w.ttft_slo, w.tpot_slo, trace.duration());
        assert_eq!(re.n_finished + re.n_failed, re.n_requests, "{}", sys.label());
        assert!(
            re.slo_attainment >= rf.slo_attainment - 0.02,
            "{}: scale-out attainment {:.3} fell below fixed-membership {:.3}",
            sys.label(),
            re.slo_attainment,
            rf.slo_attainment
        );
        assert!(
            re.goodput_tokens >= rf.goodput_tokens * 0.98,
            "{}: scale-out goodput {:.1} fell below fixed-membership {:.1}",
            sys.label(),
            re.goodput_tokens,
            rf.goodput_tokens
        );
    }
}

// ---------------------------------------------------------------------------
// SLO-class invariants (PR 8)
// ---------------------------------------------------------------------------

#[test]
fn single_class_trace_is_bit_identical_with_and_without_class_awareness() {
    // The PR 8 contract: on an all-Standard trace (every synthetic
    // workload's default), class-aware scheduling is a no-op — Standard's
    // scaled targets *are* the base SLO pair and the all-zero rank stream
    // reproduces FIFO enqueue order — so the schedule must not move by a
    // single bit relative to the pre-class builder.
    let w = catalog::by_name("azure_code").unwrap();
    let trace = {
        let t = w.generate(11).clip_seconds(60.0);
        let r = t.rate();
        t.with_rate(r * 8.0)
    };
    let base = CostModel::normalized();
    let legacy = build(System::Arrow, 8, &base, w.ttft_slo, w.tpot_slo, false).run(&trace);
    for aware in [true, false] {
        let b = build_arrow_classed(8, &base, w.ttft_slo, w.tpot_slo, aware, None).run(&trace);
        assert_eq!(legacy.records.len(), b.records.len());
        for (ra, rb) in legacy.records.iter().zip(&b.records) {
            assert_eq!(
                ra.prefill_instance, rb.prefill_instance,
                "class_aware={aware}: prefill placement moved on an all-Standard trace"
            );
            assert_eq!(ra.decode_instance, rb.decode_instance, "class_aware={aware}");
            assert_eq!(ra.state, rb.state, "class_aware={aware}");
            assert_eq!(ra.token_times.len(), rb.token_times.len());
            for (ta, tb) in ra.token_times.iter().zip(&rb.token_times) {
                assert_eq!(
                    ta.to_bits(),
                    tb.to_bits(),
                    "class_aware={aware}: token time drifted on an all-Standard trace"
                );
            }
        }
        assert_eq!(legacy.total_flips, b.total_flips, "class_aware={aware}");
        assert_eq!(legacy.total_iterations, b.total_iterations, "class_aware={aware}");
        assert_eq!(legacy.events_processed, b.events_processed, "class_aware={aware}");
    }
}

/// Instant flood of 40 heavy batch requests at t=0, then 5 light
/// interactive arrivals inside the first half second — before any batch
/// request can possibly complete (8192-token prefill + 1024 decode
/// iterations each).
fn flood_trace() -> Trace {
    let mut reqs: Vec<Request> = (0..40)
        .map(|i| Request::new(i, 0.0, 8192, 1024).with_class(SloClass::Batch))
        .collect();
    for i in 0..5u64 {
        reqs.push(
            Request::new(40 + i, 0.1 * (i + 1) as f64, 256, 16)
                .with_class(SloClass::Interactive),
        );
    }
    Trace::new("flood", reqs)
}

#[test]
fn class_aware_admission_sheds_batch_where_blind_admission_sheds_interactive() {
    // "Shed the right work": under an identical batch flood and an
    // identical in-system cap of 12, the class-aware gate refuses batch
    // at 6 (half headroom) and keeps every interactive request, while the
    // class-blind gate fills the whole cap with batch and then refuses
    // the interactive arrivals. The counts are fully determined by the
    // arrival order (no completion can land inside the first 0.5s), so
    // they are asserted exactly.
    let trace = flood_trace();
    let base = CostModel::normalized();
    let (ttft_slo, tpot_slo) = (10.0, 0.5);
    let run = |class_aware: bool| {
        let mut adm = AdmissionControl::new(12);
        adm.class_aware = class_aware;
        build_arrow_classed(4, &base, ttft_slo, tpot_slo, class_aware, Some(adm)).run(&trace)
    };
    let failed_by_class = |res: &SimResult, class: SloClass| {
        res.records
            .iter()
            .filter(|r| r.class == class && !r.finished())
            .count()
    };
    let aware = run(true);
    let blind = run(false);
    assert_eq!(aware.records.len(), trace.len());
    assert_eq!(blind.records.len(), trace.len());

    // Aware: 6 of 40 batch admitted (cap 12 x 0.5 headroom), the rest
    // shed; interactive arrivals see at most 6 + 4 = 10 in flight, under
    // the full cap, so none is ever refused and all finish.
    assert_eq!(failed_by_class(&aware, SloClass::Batch), 34);
    assert_eq!(failed_by_class(&aware, SloClass::Interactive), 0);

    // Blind: batch fills the whole cap (12 admitted, 28 shed) and every
    // interactive arrival finds 12 in flight — all 5 refused.
    assert_eq!(failed_by_class(&blind, SloClass::Batch), 28);
    assert_eq!(failed_by_class(&blind, SloClass::Interactive), 5);

    // The per-class metric agrees: interactive attainment can only be
    // better under the class-aware gate (blind's is exactly zero).
    let ra = report(&aware, ttft_slo, tpot_slo, trace.duration());
    let rb = report(&blind, ttft_slo, tpot_slo, trace.duration());
    assert_eq!(rb.class_attainment(SloClass::Interactive), 0.0);
    assert!(
        ra.class_attainment(SloClass::Interactive) >= rb.class_attainment(SloClass::Interactive)
    );
    assert_eq!(ra.n_finished + ra.n_failed, ra.n_requests);
    assert_eq!(rb.n_finished + rb.n_failed, rb.n_requests);
}
