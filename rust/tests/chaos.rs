//! Chaos conformance tier (PR 6 tentpole acceptance).
//!
//! Four contracts, every one enforced on seeded, replayable fault plans:
//!
//! * **no silent loss** — under any fault plan, every request either
//!   finishes (with exactly `output_len` tokens) or is explicitly shed
//!   with a recorded [`ShedReason`];
//! * **determinism** — the same seed produces byte-identical schedules in
//!   the calendar-cursor and heap-reference event loops, faults included;
//! * **bounded-fabric recovery** (satellite) — a flapped link over a
//!   tiny, exhaustible transfer buffer either recovers via retry /
//!   re-placement or sheds with `TransferTimeout`, identically in both
//!   loop modes;
//! * **substrate-blind degradation** (satellite) — `Liveness::Degraded`
//!   reads identically through the simulator borrow (`SimView`) and the
//!   live-server snapshot (`mirror_sim_instances`), and Arrow places
//!   identically on both.
//!
//! The end-to-end harness invariants (goodput bound, post-fault recovery)
//! are asserted through `arrow::harness::chaos` itself, so this tier
//! fails exactly when `arrow chaos` would exit non-zero.

use std::sync::Arc;

use arrow::coordinator::arrow::{ArrowConfig, ArrowPolicy};
use arrow::costmodel::CostModel;
use arrow::engine::SimInstance;
use arrow::fault::{FaultKind, FaultPlan, TransferRetryPolicy};
use arrow::harness::chaos::{run_chaos_for, ChaosConfig};
use arrow::request::{InstanceId, Request, RequestState, ShedReason};
use arrow::scenarios::{arrow_chaos, build, system_chaos, System};
use arrow::sched::{Liveness, Policy};
use arrow::server::view::mirror_sim_instances;
use arrow::sim::{Cluster, MembershipChange, SimConfig, SimResult, SimView};
use arrow::trace::catalog;
use arrow::trace::Trace;
use arrow::util::rng::Rng;

const TTFT_SLO: f64 = 2.0;
const TPOT_SLO: f64 = 0.1;

/// Prefill-heavy chaos traffic: enough sustained load that faults land on
/// busy instances, small enough to keep the tier fast.
fn chaos_trace(seed: u64) -> Trace {
    let mut rng = Rng::new(seed);
    let mut reqs = Vec::new();
    for id in 0..180u64 {
        reqs.push(Request::new(
            id,
            (id as f64) * 0.5 + rng.f64() * 0.4,
            rng.int_range(400, 8_000) as u32,
            rng.int_range(20, 120) as u32,
        ));
    }
    Trace::new("chaos-tier", reqs)
}

/// The no-silent-loss contract over one run's records.
fn assert_fully_accounted(res: &SimResult, ctx: &str) {
    for r in &res.records {
        match r.state {
            RequestState::Finished => {
                assert_eq!(
                    r.token_times.len(),
                    r.output_len as usize,
                    "{ctx}: req {} finished short of its tokens",
                    r.id
                );
                assert!(r.shed.is_none(), "{ctx}: req {} finished yet shed", r.id);
            }
            RequestState::Failed => {
                assert!(
                    r.shed.is_some(),
                    "{ctx}: req {} failed with no shed reason — silently lost",
                    r.id
                );
            }
            other => panic!("{ctx}: req {} ended in transient state {other:?}", r.id),
        }
    }
}

/// Byte-identity of two runs: same event count, same iterations, same
/// per-request schedule (states, placements, token timestamps, sheds).
fn assert_identical(a: &SimResult, b: &SimResult, ctx: &str) {
    assert_eq!(a.events_processed, b.events_processed, "{ctx}: event counts");
    assert_eq!(a.total_iterations, b.total_iterations, "{ctx}: iterations");
    assert_eq!(a.records.len(), b.records.len(), "{ctx}: record counts");
    for (x, y) in a.records.iter().zip(&b.records) {
        assert_eq!(x.state, y.state, "{ctx}: req {} state", x.id);
        assert_eq!(x.shed, y.shed, "{ctx}: req {} shed reason", x.id);
        assert_eq!(
            x.prefill_instance, y.prefill_instance,
            "{ctx}: req {} prefill placement",
            x.id
        );
        assert_eq!(
            x.decode_instance, y.decode_instance,
            "{ctx}: req {} decode placement",
            x.id
        );
        assert_eq!(x.token_times, y.token_times, "{ctx}: req {} token times", x.id);
    }
}

#[test]
fn seeded_chaos_never_silently_loses_requests() {
    let base = CostModel::h800_llama8b();
    for seed in [1u64, 7, 42] {
        let trace = chaos_trace(seed);
        let plan = FaultPlan::seeded(seed, 4, trace.duration(), 2.0);
        assert!(!plan.is_empty(), "intensity 2.0 must inject faults");
        let mut cl = arrow_chaos(4, &base, TTFT_SLO, TPOT_SLO);
        cl.schedule_fault_plan(&plan);
        let res = cl.run(&trace);
        assert_fully_accounted(&res, &format!("seed {seed}"));
        // The run must still mostly work: chaos degrades, it does not
        // collapse (all faults clear by 0.75 × duration).
        let finished = res.records.iter().filter(|r| r.finished()).count();
        assert!(
            finished * 2 > res.records.len(),
            "seed {seed}: fewer than half the requests survived ({finished}/{})",
            res.records.len()
        );
    }
}

#[test]
fn same_seed_chaos_schedules_byte_identical_across_loop_modes() {
    let base = CostModel::h800_llama8b();
    for seed in [3u64, 11, 42] {
        let trace = chaos_trace(seed);
        let plan = FaultPlan::seeded(seed ^ 0xC0FFEE, 4, trace.duration(), 1.5);
        let mut cursor = arrow_chaos(4, &base, TTFT_SLO, TPOT_SLO);
        cursor.schedule_fault_plan(&plan);
        let a = cursor.run(&trace);
        let mut reference = arrow_chaos(4, &base, TTFT_SLO, TPOT_SLO);
        reference.schedule_fault_plan(&plan);
        let b = reference.run_reference(&trace);
        assert_identical(&a, &b, &format!("seed {seed}"));
    }
}

#[test]
fn fault_free_chaos_builder_matches_its_own_baseline() {
    // An empty plan must change nothing: the fault plumbing is pure
    // overhead-free data until a fault actually fires (golden-digest
    // safety for every fault-free scenario).
    let base = CostModel::h800_llama8b();
    let trace = chaos_trace(5);
    let plain = arrow_chaos(4, &base, TTFT_SLO, TPOT_SLO).run(&trace);
    let mut armed = arrow_chaos(4, &base, TTFT_SLO, TPOT_SLO);
    armed.schedule_fault_plan(&FaultPlan::new());
    let with_empty_plan = armed.run(&trace);
    assert_identical(&plain, &with_empty_plan, "empty plan");
    assert_fully_accounted(&plain, "fault-free");
    assert!(plain.records.iter().all(|r| r.finished()));
}

#[test]
fn chaos_harness_invariants_hold_end_to_end() {
    // The exact invariants `arrow chaos` gates on (no silent loss,
    // cursor/reference determinism, goodput bound, post-horizon
    // recovery), on a CI-sized sweep.
    let w = catalog::by_name("smoke").expect("smoke workload");
    let cfg = ChaosConfig {
        clip_seconds: 30.0,
        intensities: vec![0.0, 1.5],
        gpus: 4,
        workers: 2,
        ..ChaosConfig::smoke()
    };
    let report = run_chaos_for(&w, &cfg);
    assert!(
        report.all_hold(),
        "chaos invariants failed: {:?}",
        report
            .failed()
            .iter()
            .map(|v| v.claim.as_str())
            .collect::<Vec<_>>()
    );
    assert!(report.points[1].n_faults > 0, "faulted point injected nothing");
}

/// PR 10: the scheduling adversaries inherit the chaos contracts — the
/// no-silent-loss accounting holds under seeded fault plans for both new
/// policies, through the same recovery-armed builder Arrow uses.
#[test]
fn adversary_chaos_never_silently_loses_requests() {
    let base = CostModel::h800_llama8b();
    for sys in [System::Deflect, System::Unified] {
        for seed in [7u64, 42] {
            let trace = chaos_trace(seed);
            let plan = FaultPlan::seeded(seed, 4, trace.duration(), 2.0);
            assert!(!plan.is_empty(), "intensity 2.0 must inject faults");
            let mut cl = system_chaos(sys, 4, &base, TTFT_SLO, TPOT_SLO);
            cl.schedule_fault_plan(&plan);
            let res = cl.run(&trace);
            let ctx = format!("{} seed {seed}", sys.label());
            assert_fully_accounted(&res, &ctx);
            let finished = res.records.iter().filter(|r| r.finished()).count();
            assert!(
                finished * 2 > res.records.len(),
                "{ctx}: fewer than half the requests survived ({finished}/{})",
                res.records.len()
            );
        }
    }
}

/// PR 10: cursor/heap-reference byte identity with faults, under both
/// adversaries — the PR-6 determinism contract is policy-independent.
#[test]
fn adversary_chaos_schedules_byte_identical_across_loop_modes() {
    let base = CostModel::h800_llama8b();
    for sys in [System::Deflect, System::Unified] {
        let trace = chaos_trace(11);
        let plan = FaultPlan::seeded(11 ^ 0xC0FFEE, 4, trace.duration(), 1.5);
        let mut cursor = system_chaos(sys, 4, &base, TTFT_SLO, TPOT_SLO);
        cursor.schedule_fault_plan(&plan);
        let a = cursor.run(&trace);
        let mut reference = system_chaos(sys, 4, &base, TTFT_SLO, TPOT_SLO);
        reference.schedule_fault_plan(&plan);
        let b = reference.run_reference(&trace);
        assert_identical(&a, &b, &format!("{} chaos", sys.label()));
    }
}

/// PR 10: a deflected prefill whose target decode instance crashes is
/// recovered by the PR-3 machinery — requeued, re-placed off the dead
/// slot, and finished with its full token count.
///
/// Construction: four huge prefills press the seed prefill pool (0, 1)
/// far past the TTFT target, then a stream of cap-sized prefills arrives
/// and deflects onto the decode instances (2, 3). The fault-free run
/// identifies a victim — a small prefill placed on instance 3 whose
/// first token lands *after* the chosen crash time, so at that moment
/// its work lives on instance 3 — and the fault run kills instance 3 at
/// exactly that time. Determinism makes the two runs identical up to the
/// crash, so the victim's exposure is guaranteed, not probabilistic.
#[test]
fn deflected_prefill_on_crashed_target_restarts_elsewhere() {
    let base = CostModel::h800_llama8b();
    let mut reqs = Vec::new();
    // Pool pressure: ~10s of prefill backlog per seed prefill instance.
    for id in 0..4u64 {
        reqs.push(Request::new(id, 0.0, 100_000, 10));
    }
    // Deflectable stream: well under the one-chunk deflection cap.
    for i in 0..20u64 {
        reqs.push(Request::new(4 + i, 0.001 * (i + 1) as f64, 1_500, 20));
    }
    let trace = Trace::new("deflect-recovery", reqs);

    // Fault-free baseline: the smalls must actually deflect (no flip was
    // burned, yet they sit on decode-side instances), spread over both
    // targets, and instance 3 must carry some of them.
    let baseline = build(System::Deflect, 4, &base, TTFT_SLO, TPOT_SLO, false).run(&trace);
    assert_fully_accounted(&baseline, "baseline");
    assert!(
        baseline.records.iter().all(|r| r.finished()),
        "fault-free baseline must finish everything"
    );
    assert_eq!(baseline.total_flips, 0, "pressure must deflect, not flip");
    let small_on = |res: &SimResult, inst: usize| -> Vec<u64> {
        res.records
            .iter()
            .filter(|r| r.id.0 >= 4 && r.prefill_instance == Some(InstanceId(inst)))
            .map(|r| r.id.0)
            .collect()
    };
    assert!(
        !small_on(&baseline, 2).is_empty() && !small_on(&baseline, 3).is_empty(),
        "deflections must spread over both decode instances"
    );

    // Pick the crash time from the baseline: half-way to the latest
    // first token among instance-3 smalls. Everything scheduled before
    // that instant replays identically in the fault run.
    let t_fail = baseline
        .records
        .iter()
        .filter(|r| r.id.0 >= 4 && r.prefill_instance == Some(InstanceId(3)))
        .map(|r| r.token_times[0])
        .fold(0.0f64, f64::max)
        * 0.5;
    let victims: Vec<u64> = baseline
        .records
        .iter()
        .filter(|r| {
            r.id.0 >= 4
                && r.prefill_instance == Some(InstanceId(3))
                && r.token_times[0] > t_fail
        })
        .map(|r| r.id.0)
        .collect();
    assert!(
        !victims.is_empty() && t_fail > 0.021,
        "victim selection degenerated (t_fail={t_fail})"
    );

    let mut cl = build(System::Deflect, 4, &base, TTFT_SLO, TPOT_SLO, false);
    cl.schedule_membership(t_fail, MembershipChange::Fail(3));
    let failed = cl.run(&trace);
    assert_fully_accounted(&failed, "crashed target");
    for r in &failed.records {
        if !victims.contains(&r.id.0) {
            continue;
        }
        assert_eq!(
            r.state,
            RequestState::Finished,
            "victim {} must be recovered, not shed",
            r.id
        );
        assert_ne!(
            r.prefill_instance,
            Some(InstanceId(3)),
            "victim {} must be re-placed off the dead instance",
            r.id
        );
    }
}

/// Satellite: buffer exhaustion + fail_timeout on a flapped link. The
/// fabric here is tiny (one mid-size KV fills it) and the flap covers the
/// whole burst, so transfers must queue, time out, retry with backoff,
/// and escalate — and the outcome must be the same in both loop modes.
#[test]
fn flapped_tiny_fabric_recovers_or_sheds_identically() {
    let base = CostModel::h800_llama8b();
    let build = || {
        let n = 3;
        let cfg = SimConfig {
            record_timeline: false,
            drain_timeout: 300.0,
            transfer_buffer_tokens: Some(4_000),
            transfer_fail_timeout: Some(2.0),
            transfer_retry: Some(TransferRetryPolicy {
                max_retries: 2,
                base_delay_s: 0.25,
                max_delay_s: 2.0,
                seed: 7,
            }),
            straggler_factor: Some(3.0),
            ..Default::default()
        };
        let policy = ArrowPolicy::new(ArrowConfig::new(TTFT_SLO, TPOT_SLO, n), n);
        let cost = Arc::new(base.clone());
        let instances: Vec<SimInstance> = (0..n)
            .map(|i| SimInstance::new(InstanceId(i), Arc::clone(&cost)))
            .collect();
        let mut cl = Cluster::new(instances, Box::new(policy), cfg);
        // Every link out of every instance flaps across the busy window:
        // any migration in that span hits a dead fabric.
        for link in 0..n {
            cl.schedule_fault(10.0, FaultKind::TransferFlap { link, window: 40.0 });
        }
        cl
    };
    let trace = chaos_trace(13);
    let a = build().run(&trace);
    let b = build().run_reference(&trace);
    assert_identical(&a, &b, "flapped fabric");
    assert_fully_accounted(&a, "flapped fabric");
    // Anything that did fail, failed for a flap-shaped reason (the
    // transfer path, or the end-of-run force-fail of work the flap
    // stalled) — never capacity or size pressure, which would mean the
    // flap corrupted unrelated accounting.
    for r in &a.records {
        if r.state == RequestState::Failed {
            assert!(
                matches!(
                    r.shed,
                    Some(ShedReason::TransferTimeout) | Some(ShedReason::DeadlineExceeded)
                ),
                "req {}: flap-era failure with reason {:?}",
                r.id,
                r.shed
            );
        }
    }
    // And the run as a whole survived the flap.
    let finished = a.records.iter().filter(|r| r.finished()).count();
    assert!(
        finished * 2 > a.records.len(),
        "flapped fabric collapsed the run ({finished}/{})",
        a.records.len()
    );
}

/// Satellite: `Liveness::Degraded` is substrate-blind — the simulator
/// borrow and the live-server snapshot report it identically, and Arrow
/// makes identical placements over both.
#[test]
fn degraded_liveness_identical_across_adapters() {
    use arrow::sched::ClusterView;
    let n = 4;
    let base = CostModel::h800_llama8b();
    let mut insts: Vec<SimInstance> = (0..n)
        .map(|i| SimInstance::new(InstanceId(i), base.clone()))
        .collect();
    insts[2].life = Liveness::Degraded;

    // The adapters agree on what Degraded *is*.
    let snap = mirror_sim_instances(&insts);
    for i in 0..n {
        let (sim_l, srv_l) = (SimView(&insts).liveness(i), snap.liveness(i));
        assert_eq!(sim_l, srv_l, "inst {i}: liveness diverged across adapters");
        assert_eq!(sim_l.is_degraded(), i == 2);
        assert!(sim_l.placeable() && sim_l.in_cluster());
    }

    // And on what Degraded *means*: identical (deprioritized) placements.
    let mut sim_policy = ArrowPolicy::new(ArrowConfig::new(TTFT_SLO, TPOT_SLO, n), n);
    let mut srv_policy = ArrowPolicy::new(ArrowConfig::new(TTFT_SLO, TPOT_SLO, n), n);
    sim_policy.init(&SimView(&insts));
    srv_policy.init(&SimView(&insts));
    let mut rng = Rng::new(21);
    for step in 0..60u64 {
        let r = Request::new(step, step as f64, rng.int_range(100, 20_000) as u32, 16);
        let snap = mirror_sim_instances(&insts);
        let a = sim_policy.place_prefill(step as f64, &r, &SimView(&insts));
        let b = srv_policy.place_prefill(step as f64, &r, &snap);
        assert_eq!(a, b, "step {step}: placement diverged with a degraded member");
        assert_ne!(
            a,
            InstanceId(2),
            "step {step}: a lightly-loaded cluster must route around the straggler"
        );
        assert_eq!(
            sim_policy.pool_sizes(),
            srv_policy.pool_sizes(),
            "step {step}: pool states diverged"
        );
    }
}
