//! L1/L2 execution bench: real PJRT latencies of the AOT artifacts —
//! prefill per bucket and decode per batch occupancy. These numbers feed
//! the cost-model calibration (EXPERIMENTS.md §Calib) and gate the
//! runtime hot path (KV marshalling overhead).
//!
//! Skips gracefully when `artifacts/` is absent (run `make artifacts`).

use std::time::Instant;

use arrow::runtime::ModelRuntime;
use arrow::util::benchkit::fmt_dur;

fn main() {
    let dir = std::env::var("ARROW_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    if !std::path::Path::new(&dir).join("model_config.json").exists() {
        println!("runtime_exec: no artifacts at '{dir}' — run `make artifacts`; skipping.");
        return;
    }
    let t0 = Instant::now();
    let rt = match ModelRuntime::load(&dir) {
        Ok(rt) => rt,
        Err(e) => {
            println!("runtime_exec: cannot load artifacts: {e}; skipping.");
            return;
        }
    };
    println!(
        "loaded '{}' ({:.1}M params) + compiled {} executables in {}",
        rt.info.name,
        rt.info.n_params as f64 / 1e6,
        rt.info.prefill_buckets.len() + 1,
        fmt_dur(t0.elapsed().as_secs_f64())
    );

    println!("\n== prefill latency per bucket ==");
    for &bucket in &rt.info.prefill_buckets.clone() {
        let prompt: Vec<i32> = (0..bucket as i32).map(|i| i % 101 + 1).collect();
        rt.prefill(&prompt).unwrap(); // warmup
        let reps = 5;
        let t0 = Instant::now();
        for _ in 0..reps {
            let out = rt.prefill(&prompt).unwrap();
            std::hint::black_box(out.first_token);
        }
        let dt = t0.elapsed().as_secs_f64() / reps as f64;
        println!(
            "  s={bucket:<5} {:>10}  ({:.1} tokens/s)",
            fmt_dur(dt),
            bucket as f64 / dt
        );
    }

    println!("\n== decode latency vs batch occupancy ==");
    let prompt: Vec<i32> = (1..32).collect();
    let pre = rt.prefill(&prompt).unwrap();
    for active in 1..=rt.info.decode_batch {
        let mut st = rt.new_decode_state();
        for slot in 0..active {
            st.insert_prefill(slot, prompt.len(), &pre.k, &pre.v, pre.first_token, pre.bucket);
        }
        rt.decode_step(&mut st).unwrap(); // warmup
        let reps = 10;
        let t0 = Instant::now();
        for _ in 0..reps {
            rt.decode_step(&mut st).unwrap();
        }
        let dt = t0.elapsed().as_secs_f64() / reps as f64;
        println!(
            "  batch={active} tokens={:<5} {:>10}  ({:.1} tokens/s)",
            st.total_cached_tokens(),
            fmt_dur(dt),
            active as f64 / dt
        );
    }

    println!("\n== KV handoff (migration memcpy) ==");
    let mut st = rt.new_decode_state();
    let reps = 50;
    let t0 = Instant::now();
    for i in 0..reps {
        st.insert_prefill(
            (i % rt.info.decode_batch as u64) as usize,
            prompt.len(),
            &pre.k,
            &pre.v,
            pre.first_token,
            pre.bucket,
        );
    }
    let dt = t0.elapsed().as_secs_f64() / reps as f64;
    let bytes = pre.k.len() * 8; // k + v, f32
    println!(
        "  insert_prefill: {:>10} for {:.1} KB  ({:.2} GB/s)",
        fmt_dur(dt),
        bytes as f64 / 1024.0,
        bytes as f64 / dt / 1e9
    );
}
