//! Streaming-sweep memory gate: peak allocation must be flat in trace
//! length (PR 7 tentpole acceptance).
//!
//! The streamed path (`ArrivalSource` → event loop slot window →
//! `StreamingSlo` sink) is supposed to hold simulation memory at
//! O(instances + in-flight requests), independent of how many requests
//! flow through. This bench proves it with a counting global allocator:
//! it runs the same synthetic workload at a base request count and at
//! 10× the base count, and asserts the larger run's peak allocation is
//! within `ARROW_SWEEP_MAX_MEM_RATIO` (default 1.1×) of the smaller
//! run's — while the event loop still clears `ARROW_BENCH_MIN_EPS`
//! (default 1,000,000) events/s on the large run.
//!
//! Modes:
//! * default — full measurement: both streamed runs plus a retained
//!   (materialized-trace) run at the base count for contrast, emitting
//!   `BENCH_sweep.json`;
//! * `ARROW_BENCH_SMOKE=1` — CI gate: the two streamed runs only;
//!   process exits non-zero if either the memory-flatness or the
//!   throughput floor fails.
//!
//! Knobs: `ARROW_SWEEP_BASE_REQS` (default 1,000,000), `ARROW_SWEEP_REQS`
//! (default 10,000,000), `ARROW_SWEEP_RPS` (arrival rate, default 96 —
//! the in-flight window, and therefore the expected peak, is rate ×
//! latency, so both runs see the same steady state), `ARROW_BENCH_OUT`.

use std::alloc::{GlobalAlloc, Layout, System as SystemAlloc};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::Instant;

use arrow::costmodel::CostModel;
use arrow::json::Json;
use arrow::metrics::StreamingSlo;
use arrow::scenarios::{build, System};
use arrow::trace::stream::SyntheticSource;
use arrow::trace::synthetic;
use arrow::util::benchkit::{env_f64, fmt_dur};

// ---------------------------------------------------------------------------
// Counting allocator: live bytes + high-water mark.
// ---------------------------------------------------------------------------

static CURRENT: AtomicUsize = AtomicUsize::new(0);
static PEAK: AtomicUsize = AtomicUsize::new(0);

struct CountingAlloc;

fn count_add(n: usize) {
    let cur = CURRENT.fetch_add(n, Ordering::Relaxed) + n;
    let mut peak = PEAK.load(Ordering::Relaxed);
    while cur > peak {
        match PEAK.compare_exchange_weak(peak, cur, Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => break,
            Err(p) => peak = p,
        }
    }
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let p = SystemAlloc.alloc(layout);
        if !p.is_null() {
            count_add(layout.size());
        }
        p
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        SystemAlloc.dealloc(ptr, layout);
        CURRENT.fetch_sub(layout.size(), Ordering::Relaxed);
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        let p = SystemAlloc.realloc(ptr, layout, new_size);
        if !p.is_null() {
            if new_size >= layout.size() {
                count_add(new_size - layout.size());
            } else {
                CURRENT.fetch_sub(layout.size() - new_size, Ordering::Relaxed);
            }
        }
        p
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

/// Start a fresh high-water measurement from the current live set.
fn reset_peak() {
    PEAK.store(CURRENT.load(Ordering::Relaxed), Ordering::Relaxed);
}

fn peak_bytes() -> usize {
    PEAK.load(Ordering::Relaxed)
}

// ---------------------------------------------------------------------------
// The sweep runs.
// ---------------------------------------------------------------------------

const SEED: u64 = 7;
const TTFT_SLO: f64 = 2.0;
const TPOT_SLO: f64 = 0.1;

struct RunStats {
    label: String,
    requests: u64,
    events: u64,
    iterations: u64,
    seconds: f64,
    events_per_sec: f64,
    peak_bytes: usize,
}

impl RunStats {
    fn json(&self) -> Json {
        Json::obj(vec![
            ("label", Json::Str(self.label.clone())),
            ("requests", Json::Num(self.requests as f64)),
            ("events", Json::Num(self.events as f64)),
            ("iterations", Json::Num(self.iterations as f64)),
            ("seconds", Json::Num(self.seconds)),
            ("events_per_sec", Json::Num(self.events_per_sec)),
            ("peak_alloc_bytes", Json::Num(self.peak_bytes as f64)),
        ])
    }

    fn print(&self) {
        println!(
            "{:<16} {:>9} reqs  {:>10} events in {:>9}  -> {:>10.0} events/s, peak {:.1} MiB",
            self.label,
            self.requests,
            self.events,
            fmt_dur(self.seconds),
            self.events_per_sec,
            self.peak_bytes as f64 / (1024.0 * 1024.0)
        );
    }
}

/// One streamed run: lazy synthetic arrivals into the constant-memory SLO
/// sink. Nothing O(n) is allocated on this path — that is the claim the
/// peak counter checks.
fn streamed_run(n: usize, rps: f64, label: &str) -> RunStats {
    let minutes = ((n as f64 / (rps * 60.0)).ceil() as usize).max(1);
    let spec = synthetic::smoke(n, minutes);
    reset_peak();
    let t0 = Instant::now();
    let cl = build(System::Arrow, 8, &CostModel::normalized(), TTFT_SLO, TPOT_SLO, false);
    let mut src = SyntheticSource::new(&spec, SEED);
    let mut slo = StreamingSlo::new(TTFT_SLO, TPOT_SLO);
    let res = cl.run_streamed(&mut src, &mut |r| slo.observe(&r));
    let seconds = t0.elapsed().as_secs_f64();
    RunStats {
        label: label.to_string(),
        requests: slo.observed() as u64,
        events: res.events_processed,
        iterations: res.total_iterations,
        seconds,
        events_per_sec: res.events_processed as f64 / seconds,
        peak_bytes: peak_bytes(),
    }
}

/// Retained-mode contrast run (full measurement only): materialize the
/// trace and keep every record — the O(n) memory profile the streaming
/// path retires from the sweep loop.
fn retained_run(n: usize, rps: f64) -> RunStats {
    let minutes = ((n as f64 / (rps * 60.0)).ceil() as usize).max(1);
    let spec = synthetic::smoke(n, minutes);
    reset_peak();
    let t0 = Instant::now();
    let trace = spec.generate(SEED);
    let cl = build(System::Arrow, 8, &CostModel::normalized(), TTFT_SLO, TPOT_SLO, false);
    let res = cl.run(&trace);
    let seconds = t0.elapsed().as_secs_f64();
    RunStats {
        label: "retained-base".to_string(),
        requests: res.records.len() as u64,
        events: res.events_processed,
        iterations: res.total_iterations,
        seconds,
        events_per_sec: res.events_processed as f64 / seconds,
        peak_bytes: peak_bytes(),
    }
}

fn main() {
    let smoke = std::env::var("ARROW_BENCH_SMOKE").map_or(false, |v| v != "0" && !v.is_empty());
    let base_n = env_f64("ARROW_SWEEP_BASE_REQS", 1.0e6) as usize;
    let big_n = env_f64("ARROW_SWEEP_REQS", 1.0e7) as usize;
    let rps = env_f64("ARROW_SWEEP_RPS", 96.0);
    let max_ratio = env_f64("ARROW_SWEEP_MAX_MEM_RATIO", 1.1);
    let min_eps = env_f64("ARROW_BENCH_MIN_EPS", 1.0e6);

    println!(
        "== streaming sweep memory gate{} ==",
        if smoke { " (smoke)" } else { "" }
    );
    println!(
        "workload: smoke spec @ {rps:.0} req/s, {base_n} -> {big_n} requests; \
         gates: peak <= {max_ratio:.2}x base, >= {min_eps:.0} events/s\n"
    );

    // Peak high-water marks are monotone within a measurement window, so
    // each run resets the mark to the current live set first; the base
    // run goes first so its transient state is freed before the big one.
    let base = streamed_run(base_n, rps, "streamed-base");
    base.print();
    let big = streamed_run(big_n, rps, "streamed-10x");
    big.print();

    let mut runs = vec![base.json(), big.json()];
    let mut retained = Json::Null;
    if !smoke {
        let r = retained_run(base_n, rps);
        r.print();
        println!(
            "retained/streamed peak at {base_n} reqs: {:.1}x",
            r.peak_bytes as f64 / base.peak_bytes.max(1) as f64
        );
        retained = r.json();
        runs.push(retained.clone());
    }

    let ratio = big.peak_bytes as f64 / base.peak_bytes.max(1) as f64;
    println!(
        "\npeak allocation: base {:.1} MiB, 10x {:.1} MiB -> ratio {ratio:.3} \
         (gate <= {max_ratio:.2})",
        base.peak_bytes as f64 / (1024.0 * 1024.0),
        big.peak_bytes as f64 / (1024.0 * 1024.0)
    );

    let out = Json::obj(vec![
        ("bench", Json::Str("sweep".into())),
        ("smoke", Json::Bool(smoke)),
        ("base_requests", Json::Num(base_n as f64)),
        ("requests", Json::Num(big_n as f64)),
        ("rps", Json::Num(rps)),
        ("target_max_mem_ratio", Json::Num(max_ratio)),
        ("target_events_per_sec", Json::Num(min_eps)),
        ("runs", Json::Arr(runs)),
        ("retained_base", retained),
        // benchdiff headlines: throughput (higher is better) and memory
        // (lower is better), both from the 10x streamed run.
        ("events_per_sec", Json::Num(big.events_per_sec)),
        ("peak_alloc_bytes", Json::Num(big.peak_bytes as f64)),
        ("peak_ratio", Json::Num(ratio)),
    ]);
    let path = std::env::var("ARROW_BENCH_OUT").unwrap_or_else(|_| "BENCH_sweep.json".into());
    match std::fs::write(&path, out.encode()) {
        Ok(()) => println!("-> {path}"),
        Err(e) => eprintln!("warn: cannot write {path}: {e}"),
    }

    // Only the smoke (CI) mode gates; a full measurement run must always
    // succeed so the JSON can be regenerated on slower hardware.
    if smoke {
        let mut failed = false;
        if ratio > max_ratio {
            eprintln!(
                "FAIL: peak allocation grew {ratio:.3}x from {base_n} to {big_n} requests \
                 (gate {max_ratio:.2}x) — the sweep path is not O(in-flight)"
            );
            failed = true;
        }
        if big.events_per_sec < min_eps {
            eprintln!(
                "FAIL: streamed event throughput {:.0} events/s below the {min_eps:.0} gate",
                big.events_per_sec
            );
            failed = true;
        }
        if failed {
            std::process::exit(1);
        }
        println!(
            "gate OK: peak ratio {ratio:.3} <= {max_ratio:.2}, {:.0} events/s >= {min_eps:.0}",
            big.events_per_sec
        );
    }
}
