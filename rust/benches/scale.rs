//! Scale-sweep bench (PR 4): placement decisions/s as the cluster grows.
//!
//! The PR-4 tentpole makes a placement decision O(1) per candidate (no
//! queue walk — moment-based delay) and near-independent of cluster size
//! (keyed argmin index + change-epoch refresh skip). This bench proves
//! it: the same loaded-cluster microbench as `benches/scheduler.rs`,
//! swept over 4 → 256 instances, plus an end-to-end deep-queue-burst run
//! through `scenarios::large_cluster`.
//!
//! Two regimes are measured per cluster size, so the gate exercises both
//! halves of the PR-4 design rather than only the cached fast path:
//! * **quiescent** — `Epoched` view with a constant clock (nothing
//!   changed since the last decision): placement is a pure argmin-index
//!   read. This is the path whose cost must be ~independent of cluster
//!   size, so the 4 → 256 *flatness* gate runs here.
//! * **churned** — the view's epoch advances every decision (the
//!   steady-state of a busy simulator, and the live server's permanent
//!   `EPOCH_UNKNOWN` regime): every placement re-runs the index-refresh
//!   verify scan over the per-instance O(1) aggregates. This is where a
//!   regression that re-introduces queue walks would show, so the
//!   *absolute floor* gate runs here.
//!
//! Modes (mirrors the other benches):
//! * default — full measurement, emitting `BENCH_scale.json`;
//! * `ARROW_BENCH_SMOKE=1` — CI gate, exits non-zero if
//!   * quiescent decisions/s at 256 instances <
//!     `ARROW_BENCH_MIN_FLATNESS` (default 0.5) × the 4-instance rate,
//!     for either placement path — the "flat at scale" criterion — or
//!   * either churned placement path at 256 instances drops below
//!     `ARROW_BENCH_MIN_CHURN_DPS` (default 50,000) decisions/s —
//!     ≤ 20 µs/decision even when every decision re-verifies all 256
//!     instances' aggregates (the pre-PR-4 walk, O(members × depth),
//!     sat near ~80 µs on this workload and fails this floor).
//!
//! `ARROW_BENCH_OUT` overrides the JSON output path.

use std::time::Instant;

use arrow::coordinator::arrow::{ArrowConfig, ArrowPolicy};
use arrow::costmodel::CostModel;
use arrow::engine::SimInstance;
use arrow::json::Json;
use arrow::request::{InstanceId, Request, RequestId};
use arrow::scenarios;
use arrow::sched::{Epoched, Policy};
use arrow::sim::SimView;
use arrow::util::benchkit::{black_box, env_f64, fmt_dur, Bencher};
use arrow::util::rng::Rng;

const DEFAULT_MIN_CHURN_DPS: f64 = 50_000.0;
const DEFAULT_MIN_FLATNESS: f64 = 0.5;
const SWEEP: [usize; 4] = [4, 16, 64, 256];
const QUEUE_DEPTH: usize = 32;

/// Deep queues on every instance + moderate decode residency: the state
/// a large cluster is in mid-burst, when placement cost matters most.
fn loaded_cluster(n: usize, depth: usize, seed: u64) -> Vec<SimInstance> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|i| {
            let mut inst = SimInstance::new(InstanceId(i), CostModel::h800_llama8b());
            for q in 0..depth {
                inst.enqueue_prefill(
                    RequestId((i * depth + q) as u64),
                    rng.int_range(200, 20_000) as u32,
                );
            }
            let kv = rng.int_range(2_000, 20_000) as u64;
            assert!(inst.try_reserve_kv(kv));
            inst.enqueue_decode(RequestId(900_000 + i as u64), kv as u32, 100);
            inst
        })
        .collect()
}

fn main() {
    let smoke = std::env::var("ARROW_BENCH_SMOKE").map_or(false, |v| v != "0" && !v.is_empty());
    let min_churn_dps = env_f64("ARROW_BENCH_MIN_CHURN_DPS", DEFAULT_MIN_CHURN_DPS);
    let min_flatness = env_f64("ARROW_BENCH_MIN_FLATNESS", DEFAULT_MIN_FLATNESS);
    let mut b = if smoke { Bencher::quick() } else { Bencher::new() };
    println!(
        "== placement decisions/s vs cluster size (PR 4 scale gate){} ==",
        if smoke { " (smoke)" } else { "" }
    );

    let mut rows = Vec::new();
    let mut quiescent = [Vec::new(), Vec::new()]; // [prefill, decode] per n
    let mut churned = [Vec::new(), Vec::new()];
    for &n in &SWEEP {
        let instances = loaded_cluster(n, QUEUE_DEPTH, 7);
        // Generous SLOs keep Alg. 1/2 on their first-branch argmin: the
        // sweep measures the *indexed placement path*, not flip churn.
        let mut policy = ArrowPolicy::new(ArrowConfig::new(1e9, 1.0, n), n);
        policy.init(&SimView(&instances));
        let mut rng = Rng::new(1);
        let mut id = 0u64;
        // Quiescent: constant clock — refresh is an O(1) skip, placement
        // is the pure index read whose flatness the gate asserts.
        let r = b.bench(&format!("quiescent place_prefill n={n:>3}"), || {
            id += 1;
            let req = Request::new(id, 0.0, rng.int_range(100, 30_000) as u32, 50);
            black_box(policy.place_prefill(0.0, &req, &Epoched(SimView(&instances), 1)))
        });
        quiescent[0].push(r.per_sec());
        let r = b.bench(&format!("quiescent place_decode  n={n:>3}"), || {
            id += 1;
            let req = Request::new(id, 0.0, 2_000, 50);
            black_box(policy.place_decode(
                0.0,
                &req,
                InstanceId(0),
                &Epoched(SimView(&instances), 1),
            ))
        });
        quiescent[1].push(r.per_sec());
        // Churned: a fresh epoch per decision forces the verify scan
        // over every instance's O(1) aggregates — the busy-simulator /
        // live-server steady state, where a reintroduced queue walk
        // would immediately show up.
        let mut epoch = 1u64;
        let r = b.bench(&format!("churned   place_prefill n={n:>3}"), || {
            id += 1;
            epoch += 1;
            let req = Request::new(id, 0.0, rng.int_range(100, 30_000) as u32, 50);
            black_box(policy.place_prefill(0.0, &req, &Epoched(SimView(&instances), epoch)))
        });
        churned[0].push(r.per_sec());
        let r = b.bench(&format!("churned   place_decode  n={n:>3}"), || {
            id += 1;
            epoch += 1;
            let req = Request::new(id, 0.0, 2_000, 50);
            black_box(policy.place_decode(
                0.0,
                &req,
                InstanceId(0),
                &Epoched(SimView(&instances), epoch),
            ))
        });
        churned[1].push(r.per_sec());
        let last = |v: &[Vec<f64>; 2], k: usize| v[k][v[k].len() - 1];
        rows.push(Json::obj(vec![
            ("instances", Json::Num(n as f64)),
            ("queue_depth", Json::Num(QUEUE_DEPTH as f64)),
            ("quiescent_place_prefill_per_sec", Json::Num(last(&quiescent, 0))),
            ("quiescent_place_decode_per_sec", Json::Num(last(&quiescent, 1))),
            ("churned_place_prefill_per_sec", Json::Num(last(&churned, 0))),
            ("churned_place_decode_per_sec", Json::Num(last(&churned, 1))),
        ]));
    }

    // The gated quantities: quiescent flatness 4 -> 256, and the churned
    // absolute floor at the largest size.
    let flatness_prefill = quiescent[0][SWEEP.len() - 1] / quiescent[0][0];
    let flatness_decode = quiescent[1][SWEEP.len() - 1] / quiescent[1][0];
    let churn_floor = churned[0][SWEEP.len() - 1].min(churned[1][SWEEP.len() - 1]);
    let min_measured = quiescent
        .iter()
        .chain(churned.iter())
        .flatten()
        .fold(f64::INFINITY, |a, &b| a.min(b));
    println!(
        "\nquiescent flatness 4 -> 256: place_prefill {flatness_prefill:.2}x, \
         place_decode {flatness_decode:.2}x (gate >= {min_flatness}); \
         churned floor at 256: {churn_floor:.0}/s (gate >= {min_churn_dps:.0})"
    );

    // End-to-end proof at scale: a large Arrow cluster draining a
    // deep-queue burst through the full event loop (informational — the
    // simulator gate lives in benches/simulator.rs).
    let (e2e_n, per_inst) = if smoke { (64, 4) } else { (256, 8) };
    let trace = scenarios::deep_queue_burst(e2e_n, per_inst, 10.0, 3);
    let cl = scenarios::large_cluster(e2e_n, &CostModel::h800_llama8b(), 5.0, 0.1);
    let t0 = Instant::now();
    let res = cl.run(&trace);
    let dt = t0.elapsed().as_secs_f64();
    let finished = res.records.iter().filter(|r| r.finished()).count();
    println!(
        "e2e large_cluster({e2e_n}): {} reqs ({finished} finished), {} events in {} \
         ({:.0} events/s)",
        trace.len(),
        res.events_processed,
        fmt_dur(dt),
        res.events_processed as f64 / dt
    );

    let out = Json::obj(vec![
        ("bench", Json::Str("scale".into())),
        ("smoke", Json::Bool(smoke)),
        ("queue_depth", Json::Num(QUEUE_DEPTH as f64)),
        ("target_churned_decisions_per_sec", Json::Num(min_churn_dps)),
        ("target_flatness", Json::Num(min_flatness)),
        ("sweep", Json::Arr(rows)),
        ("flatness_place_prefill", Json::Num(flatness_prefill)),
        ("flatness_place_decode", Json::Num(flatness_decode)),
        ("churned_floor_decisions_per_sec", Json::Num(churn_floor)),
        ("min_decisions_per_sec", Json::Num(min_measured)),
        (
            "e2e",
            Json::obj(vec![
                ("instances", Json::Num(e2e_n as f64)),
                ("requests", Json::Num(trace.len() as f64)),
                ("finished", Json::Num(finished as f64)),
                ("events", Json::Num(res.events_processed as f64)),
                ("seconds", Json::Num(dt)),
                (
                    "events_per_sec",
                    Json::Num(res.events_processed as f64 / dt),
                ),
            ]),
        ),
    ]);
    let path = std::env::var("ARROW_BENCH_OUT").unwrap_or_else(|_| "BENCH_scale.json".into());
    match std::fs::write(&path, out.encode()) {
        Ok(()) => println!("\n-> {path}"),
        Err(e) => eprintln!("warn: cannot write {path}: {e}"),
    }

    if smoke {
        let mut failed = false;
        if flatness_prefill < min_flatness || flatness_decode < min_flatness {
            eprintln!(
                "FAIL: quiescent decisions/s not flat at scale (prefill \
                 {flatness_prefill:.2}x, decode {flatness_decode:.2}x < {min_flatness}x \
                 from 4 -> 256 instances)"
            );
            failed = true;
        }
        if churn_floor < min_churn_dps {
            eprintln!(
                "FAIL: churned placement at 256 instances {churn_floor:.0}/s below the \
                 {min_churn_dps:.0} floor (a queue walk crept back into the refresh path?)"
            );
            failed = true;
        }
        if failed {
            std::process::exit(1);
        }
        println!(
            "gate OK: quiescent flatness >= {min_flatness}x and churned placement at 256 \
             instances >= {min_churn_dps:.0} decisions/s"
        );
    }
}
