//! L3 hot-path microbenchmarks: scheduler decision latency.
//!
//! The Arrow global scheduler sits on the request path of every arriving
//! request; its placement decision must be negligible next to a ~10 ms
//! model iteration. Target (DESIGN.md §9): well under 1 ms/decision even
//! on a loaded 64-instance cluster.

use arrow::coordinator::arrow::{ArrowConfig, ArrowPolicy};
use arrow::coordinator::predictor::TtftPredictor;
use arrow::costmodel::CostModel;
use arrow::engine::SimInstance;
use arrow::request::{InstanceId, Request, RequestId};
use arrow::sim::policy::Policy;
use arrow::util::benchkit::{black_box, Bencher};
use arrow::util::rng::Rng;

fn loaded_cluster(n: usize, queue_depth: usize, seed: u64) -> Vec<SimInstance> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|i| {
            let mut inst = SimInstance::new(InstanceId(i), CostModel::h800_llama8b());
            for q in 0..queue_depth {
                inst.enqueue_prefill(
                    RequestId((i * queue_depth + q) as u64),
                    rng.int_range(200, 20_000) as u32,
                );
            }
            let kv = rng.int_range(1_000, 200_000) as u64;
            assert!(inst.try_reserve_kv(kv));
            inst.enqueue_decode(RequestId(900_000 + i as u64), kv as u32, 100);
            inst
        })
        .collect()
}

fn main() {
    let mut b = Bencher::new();
    println!("== scheduler decision latency (L3 hot path) ==");

    for &(n, depth) in &[(8usize, 4usize), (16, 8), (64, 16)] {
        let instances = loaded_cluster(n, depth, 7);
        let mut policy = ArrowPolicy::new(ArrowConfig::new(3.0, 0.1, n), n);
        policy.init(&instances);
        let mut rng = Rng::new(1);
        let mut id = 0u64;
        b.bench(&format!("arrow place_prefill n={n} depth={depth}"), || {
            id += 1;
            let req = Request::new(id, 0.0, rng.int_range(100, 30_000) as u32, 50);
            black_box(policy.place_prefill(0.0, &req, &instances))
        });
        b.bench(&format!("arrow place_decode  n={n} depth={depth}"), || {
            id += 1;
            let req = Request::new(id, 0.0, 2_000, 50);
            black_box(policy.place_decode(0.0, &req, InstanceId(0), &instances))
        });
        b.bench(&format!("arrow on_tick       n={n} depth={depth}"), || {
            policy.on_tick(1.0, &instances);
        });
    }

    println!("\n== TTFT predictor ==");
    let cost = CostModel::h800_llama8b();
    let pred = TtftPredictor::profile(&cost, 2048);
    let queue: Vec<(u32, u32)> = (0..32).map(|i| (1_000 + i * 500, 800 + i * 100)).collect();
    b.bench("predictor profile+fit", || {
        black_box(TtftPredictor::profile(&cost, 2048))
    });
    b.bench("predictor queue_delay(32 queued)", || {
        black_box(pred.queue_delay(&queue))
    });

    println!("\ntarget: every decision well under 1ms — see DESIGN.md §9.");
}
