//! L3 hot-path microbenchmarks: scheduler decision latency.
//!
//! The Arrow global scheduler sits on the request path of every arriving
//! request; its placement decision must be negligible next to a ~10 ms
//! model iteration. Target (DESIGN.md §9): well under 1 ms/decision even
//! on a loaded 64-instance cluster. Decisions run through the same
//! `ClusterView` indirection as production (`sim::SimView`), so the
//! bench gates the view dispatch overhead too.
//!
//! Modes (mirrors `benches/simulator.rs`):
//! * default — full measurement, emitting `BENCH_scheduler.json` so the
//!   decision-latency trajectory is tracked PR over PR;
//! * `ARROW_BENCH_SMOKE=1` — CI gate: quick windows, process exits
//!   non-zero if any placement decision path (`place_prefill` /
//!   `place_decode`) drops below `ARROW_BENCH_MIN_DPS` (default 10,000)
//!   decisions/s — i.e. 100 µs/decision, 10× headroom on the 1 ms target.
//!
//! `ARROW_BENCH_OUT` overrides the JSON output path.

use arrow::coordinator::arrow::{ArrowConfig, ArrowPolicy};
use arrow::coordinator::predictor::TtftPredictor;
use arrow::costmodel::CostModel;
use arrow::engine::SimInstance;
use arrow::json::Json;
use arrow::request::{InstanceId, Request, RequestId};
use arrow::sched::Policy;
use arrow::sim::SimView;
use arrow::util::benchkit::{black_box, env_f64, Bencher};
use arrow::util::rng::Rng;

const DEFAULT_MIN_DPS: f64 = 10_000.0;

fn loaded_cluster(n: usize, queue_depth: usize, seed: u64) -> Vec<SimInstance> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|i| {
            let mut inst = SimInstance::new(InstanceId(i), CostModel::h800_llama8b());
            for q in 0..queue_depth {
                inst.enqueue_prefill(
                    RequestId((i * queue_depth + q) as u64),
                    rng.int_range(200, 20_000) as u32,
                );
            }
            let kv = rng.int_range(1_000, 200_000) as u64;
            assert!(inst.try_reserve_kv(kv));
            inst.enqueue_decode(RequestId(900_000 + i as u64), kv as u32, 100);
            inst
        })
        .collect()
}

fn main() {
    let smoke = std::env::var("ARROW_BENCH_SMOKE").map_or(false, |v| v != "0" && !v.is_empty());
    let min_dps = env_f64("ARROW_BENCH_MIN_DPS", DEFAULT_MIN_DPS);
    let mut b = if smoke { Bencher::quick() } else { Bencher::new() };
    println!(
        "== scheduler decision latency (L3 hot path){} ==",
        if smoke { " (smoke)" } else { "" }
    );

    let mut rows = Vec::new();
    // Worst observed placement-decision throughput — the gated quantity.
    let mut worst_placement_dps = f64::INFINITY;
    for &(n, depth) in &[(8usize, 4usize), (16, 8), (64, 16)] {
        let instances = loaded_cluster(n, depth, 7);
        let mut policy = ArrowPolicy::new(ArrowConfig::new(3.0, 0.1, n), n);
        policy.init(&SimView(&instances));
        let mut rng = Rng::new(1);
        let mut id = 0u64;
        let mut push_row = |name: &str, dps: f64, gated: bool| {
            rows.push(Json::obj(vec![
                ("decision", Json::Str(name.into())),
                ("instances", Json::Num(n as f64)),
                ("queue_depth", Json::Num(depth as f64)),
                ("decisions_per_sec", Json::Num(dps)),
                ("gated", Json::Bool(gated)),
            ]));
        };
        // A bare SimView reports EPOCH_UNKNOWN, so every decision runs
        // the PR-4 index-refresh verify scan — the same work a live
        // placement does between engine events. This keeps the 10k gate
        // on the refresh path, not just the cached-index fast path
        // (benches/scale.rs measures both regimes explicitly).
        let r = b.bench(&format!("arrow place_prefill n={n} depth={depth}"), || {
            id += 1;
            let req = Request::new(id, 0.0, rng.int_range(100, 30_000) as u32, 50);
            black_box(policy.place_prefill(0.0, &req, &SimView(&instances)))
        });
        worst_placement_dps = worst_placement_dps.min(r.per_sec());
        push_row("place_prefill", r.per_sec(), true);
        let r = b.bench(&format!("arrow place_decode  n={n} depth={depth}"), || {
            id += 1;
            let req = Request::new(id, 0.0, 2_000, 50);
            black_box(policy.place_decode(0.0, &req, InstanceId(0), &SimView(&instances)))
        });
        worst_placement_dps = worst_placement_dps.min(r.per_sec());
        push_row("place_decode", r.per_sec(), true);
        let r = b.bench(&format!("arrow on_tick       n={n} depth={depth}"), || {
            policy.on_tick(1.0, &SimView(&instances));
        });
        push_row("on_tick", r.per_sec(), false);
    }

    println!("\n== TTFT predictor ==");
    let cost = CostModel::h800_llama8b();
    let pred = TtftPredictor::profile(&cost, 2048);
    let queue: Vec<(u32, u32)> = (0..32).map(|i| (1_000 + i * 500, 800 + i * 100)).collect();
    let r = b.bench("predictor profile+fit", || {
        black_box(TtftPredictor::profile(&cost, 2048))
    });
    let profile_dps = r.per_sec();
    let r = b.bench("predictor queue_delay(32 queued)", || {
        black_box(pred.queue_delay(&queue))
    });
    let qd_dps = r.per_sec();

    let out = Json::obj(vec![
        ("bench", Json::Str("scheduler".into())),
        ("smoke", Json::Bool(smoke)),
        ("target_decisions_per_sec", Json::Num(min_dps)),
        (
            "worst_placement_decisions_per_sec",
            Json::Num(worst_placement_dps),
        ),
        ("decisions", Json::Arr(rows)),
        (
            "predictor",
            Json::obj(vec![
                ("profile_fits_per_sec", Json::Num(profile_dps)),
                ("queue_delay_32_per_sec", Json::Num(qd_dps)),
            ]),
        ),
    ]);
    let path =
        std::env::var("ARROW_BENCH_OUT").unwrap_or_else(|_| "BENCH_scheduler.json".into());
    match std::fs::write(&path, out.encode()) {
        Ok(()) => println!("\n-> {path}"),
        Err(e) => eprintln!("warn: cannot write {path}: {e}"),
    }

    // Only the smoke (CI) mode gates; a full measurement run must always
    // succeed so the JSON can be regenerated on slower hardware.
    if smoke && worst_placement_dps < min_dps {
        eprintln!(
            "FAIL: slowest placement decision {worst_placement_dps:.0}/s below the {min_dps:.0} gate"
        );
        std::process::exit(1);
    }
    if smoke {
        println!("gate OK: slowest placement {worst_placement_dps:.0} decisions/s >= {min_dps:.0}");
    }
    println!("\ntarget: every decision well under 1ms — see DESIGN.md §9.");
}
