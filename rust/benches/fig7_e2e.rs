//! End-to-end benchmark regenerating the Fig. 7 comparison rows (one per
//! paper table/figure, per the reproduction brief): for every Table-1
//! workload, the four Fig. 7 systems at a low / medium / high rate, plus
//! the headline max-sustainable-rate ratios.
//!
//! Full-resolution sweeps live in `arrow figures fig7`; this bench is the
//! fast regression gate over the same code path.

use arrow::costmodel::CostModel;
use arrow::metrics::{max_sustainable_rate, SloReport};
use arrow::scenarios::{build, System};
use arrow::trace::catalog;
use arrow::util::threads::{default_workers, parallel_map};

const SYSTEMS: [System; 4] = [
    System::Arrow,
    System::VllmColocated,
    System::VllmDisaggregated,
    System::DistServe,
];

fn main() {
    let clip = 240.0;
    println!("== Fig. 7 regression rows (clip {clip}s, 8 GPUs, target 90%) ==");
    for w in catalog::table1() {
        let trace = w.generate(1).clip_seconds(clip);
        let base = trace.rate();
        println!(
            "\n[{}] {} requests, base {:.2} req/s, SLO ttft={}s tpot={}s",
            w.name(),
            trace.len(),
            base,
            w.ttft_slo,
            w.tpot_slo
        );
        println!(
            "{:<14} {:>8} {:>8} {:>8} {:>10}",
            "system", "low", "med", "high", "max_rate"
        );
        let mults = [2.0, 8.0, 24.0];
        let jobs: Vec<(System, Option<f64>)> = SYSTEMS
            .iter()
            .flat_map(|&s| {
                mults
                    .iter()
                    .map(move |&m| (s, Some(m)))
                    .chain(std::iter::once((s, None)))
            })
            .collect();
        let results = parallel_map(jobs.clone(), default_workers(), |&(sys, mult)| {
            let eval = |rate: f64| {
                let t = trace.with_rate(rate);
                let cl = build(sys, 8, &CostModel::h800_llama8b(), w.ttft_slo, w.tpot_slo, false);
                let res = cl.run(&t);
                SloReport::from_records(&res.records, w.ttft_slo, w.tpot_slo, t.duration())
            };
            match mult {
                Some(m) => eval(base * m).slo_attainment,
                None => max_sustainable_rate(eval, base, 0.9, 0.05),
            }
        });
        let per_sys = mults.len() + 1;
        let arrow_max = results[per_sys - 1];
        for (si, sys) in SYSTEMS.iter().enumerate() {
            let r = &results[si * per_sys..(si + 1) * per_sys];
            print!(
                "{:<14} {:>8.3} {:>8.3} {:>8.3} {:>9.1}",
                sys.label(),
                r[0],
                r[1],
                r[2],
                r[3]
            );
            if *sys != System::Arrow && r[3] > 0.0 {
                print!("  (arrow {:.2}x)", arrow_max / r[3]);
            }
            println!();
        }
    }
    println!("\npaper headline: arrow 3.60-5.62x over vLLM, 4.06-7.78x over vLLM-disagg.");
}
