//! Simulator throughput bench: events/second per system.
//!
//! The fig7/8/9 sweeps run hundreds of full-trace simulations; this bench
//! gates the event-loop hot path (DESIGN.md §9 target: >= 1M events/s).
//!
//! Modes:
//! * default — full measurement (5 reps per system on the clipped
//!   azure_code workload + a full-hour scaling run), emitting
//!   `BENCH_simulator.json` so the perf trajectory is tracked PR over PR;
//! * `ARROW_BENCH_SMOKE=1` — CI gate: short clip, fewer reps, process
//!   exits non-zero if the Arrow system falls below
//!   `ARROW_BENCH_MIN_EPS` (default 1,000,000) events/s.
//!
//! `ARROW_BENCH_OUT` overrides the JSON output path.

use std::time::Instant;

use arrow::costmodel::CostModel;
use arrow::json::Json;
use arrow::scenarios::{build, System};
use arrow::trace::catalog;
use arrow::util::benchkit::{env_f64, fmt_dur};

const DEFAULT_MIN_EPS: f64 = 1.0e6;

fn main() {
    let smoke = std::env::var("ARROW_BENCH_SMOKE").map_or(false, |v| v != "0" && !v.is_empty());
    let min_eps = env_f64("ARROW_BENCH_MIN_EPS", DEFAULT_MIN_EPS);
    let (clip, reps) = if smoke { (120.0, 2) } else { (300.0, 5) };

    println!("== simulator event throughput{} ==", if smoke { " (smoke)" } else { "" });
    let w = catalog::by_name("azure_code").unwrap();
    let trace = w.generate(3).clip_seconds(clip);
    let t = trace.with_rate(trace.rate() * 8.0);
    println!(
        "workload: azure_code clip {clip}s, {} requests @ {:.1} req/s\n",
        t.len(),
        t.rate()
    );

    let mut rows = Vec::new();
    let mut arrow_eps = 0.0;
    for sys in System::all() {
        let mut events = 0u64;
        let t0 = Instant::now();
        for _ in 0..reps {
            let cl = build(sys, 8, &CostModel::h800_llama8b(), w.ttft_slo, w.tpot_slo, false);
            let res = cl.run(&t);
            events += res.events_processed;
        }
        let dt = t0.elapsed().as_secs_f64();
        let eps = events as f64 / dt;
        if sys == System::Arrow {
            arrow_eps = eps;
        }
        println!(
            "{:<14} {:>9} events in {:>9}  -> {:>10.0} events/s",
            sys.label(),
            events,
            fmt_dur(dt),
            eps
        );
        rows.push(Json::obj(vec![
            ("system", Json::Str(sys.label().into())),
            ("events", Json::Num(events as f64)),
            ("seconds", Json::Num(dt)),
            ("events_per_sec", Json::Num(eps)),
        ]));
    }

    // Full-hour scaling run (skipped in smoke mode: CI wants seconds).
    let mut full_hour = Json::Null;
    if !smoke {
        println!("\n== full-hour trace (scaling check) ==");
        let full = w.generate(3);
        let t0 = Instant::now();
        let cl = build(
            System::Arrow,
            8,
            &CostModel::h800_llama8b(),
            w.ttft_slo,
            w.tpot_slo,
            false,
        );
        let res = cl.run(&full);
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "arrow, full azure_code hour: {} requests, {} events, {} iterations \
             in {} ({:.0} events/s)",
            full.len(),
            res.events_processed,
            res.total_iterations,
            fmt_dur(dt),
            res.events_processed as f64 / dt
        );
        full_hour = Json::obj(vec![
            ("system", Json::Str("arrow".into())),
            ("requests", Json::Num(full.len() as f64)),
            ("events", Json::Num(res.events_processed as f64)),
            ("iterations", Json::Num(res.total_iterations as f64)),
            ("seconds", Json::Num(dt)),
            (
                "events_per_sec",
                Json::Num(res.events_processed as f64 / dt),
            ),
        ]);
    }

    let out = Json::obj(vec![
        ("bench", Json::Str("simulator".into())),
        ("workload", Json::Str("azure_code".into())),
        ("clip_seconds", Json::Num(clip)),
        ("rate_multiplier", Json::Num(8.0)),
        ("reps", Json::Num(reps as f64)),
        ("smoke", Json::Bool(smoke)),
        ("target_events_per_sec", Json::Num(min_eps)),
        ("systems", Json::Arr(rows)),
        ("full_hour", full_hour),
    ]);
    let path =
        std::env::var("ARROW_BENCH_OUT").unwrap_or_else(|_| "BENCH_simulator.json".into());
    match std::fs::write(&path, out.encode()) {
        Ok(()) => println!("\n-> {path}"),
        Err(e) => eprintln!("warn: cannot write {path}: {e}"),
    }

    // Only the smoke (CI) mode gates; a full measurement run must always
    // succeed so the JSON can be regenerated on slower hardware.
    if smoke && arrow_eps < min_eps {
        eprintln!(
            "FAIL: arrow event throughput {arrow_eps:.0} events/s below the {min_eps:.0} gate"
        );
        std::process::exit(1);
    }
    if smoke {
        println!("gate OK: arrow {arrow_eps:.0} events/s >= {min_eps:.0}");
    }
}
