//! Simulator throughput bench: events/second per system.
//!
//! The fig7/8/9 sweeps run hundreds of full-trace simulations; this bench
//! gates the event-loop hot path (DESIGN.md §9 target: >= 1M events/s).

use std::time::Instant;

use arrow::costmodel::CostModel;
use arrow::scenarios::{build, System};
use arrow::trace::catalog;
use arrow::util::benchkit::fmt_dur;

fn main() {
    println!("== simulator event throughput ==");
    let w = catalog::by_name("azure_code").unwrap();
    let trace = w.generate(3).clip_seconds(300.0);
    let t = trace.with_rate(trace.rate() * 8.0);
    println!(
        "workload: azure_code clip, {} requests @ {:.1} req/s\n",
        t.len(),
        t.rate()
    );
    for sys in System::all() {
        // Repeat to stabilize.
        let reps = 5;
        let mut events = 0u64;
        let t0 = Instant::now();
        for _ in 0..reps {
            let cl = build(sys, 8, &CostModel::h800_llama8b(), w.ttft_slo, w.tpot_slo, false);
            let res = cl.run(&t);
            events += res.events_processed;
        }
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "{:<14} {:>9} events in {:>9}  -> {:>10.0} events/s",
            sys.label(),
            events,
            fmt_dur(dt),
            events as f64 / dt
        );
    }

    println!("\n== full-hour trace (scaling check) ==");
    let full = w.generate(3);
    let t0 = Instant::now();
    let cl = build(
        System::Arrow,
        8,
        &CostModel::h800_llama8b(),
        w.ttft_slo,
        w.tpot_slo,
        false,
    );
    let res = cl.run(&full);
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "arrow, full azure_code hour: {} requests, {} events, {} iterations in {} ({:.0} events/s)",
        full.len(),
        res.events_processed,
        res.total_iterations,
        fmt_dur(dt),
        res.events_processed as f64 / dt
    );
}
