//! Ablation benches (Fig. 8 + DESIGN.md design-choice ablations):
//!
//! * scheduling strategy: SLO-aware vs Minimal-Load vs Round-Robin
//!   (the paper's Fig. 8 arms), and
//! * Arrow design knobs the paper calls out qualitatively: the overload
//!   guard (decode priority), the SLO-aware mixed-iteration chunk cap,
//!   and the initial pool split.

use arrow::coordinator::arrow::{ArrowConfig, ArrowPolicy};
use arrow::costmodel::CostModel;
use arrow::engine::SimInstance;
use arrow::metrics::SloReport;
use arrow::request::InstanceId;
use arrow::scenarios::{build, System};
use arrow::sim::{Cluster, SimConfig};
use arrow::trace::catalog;
use arrow::trace::Trace;
use arrow::util::threads::{default_workers, parallel_map};

fn arrow_cluster_with(
    n: usize,
    ttft_slo: f64,
    tpot_slo: f64,
    initial_prefill: usize,
    low_watermark: f64,
    chunk_cap: bool,
) -> Cluster {
    let mut cfg = ArrowConfig::new(ttft_slo, tpot_slo, n);
    cfg.initial_prefill = initial_prefill;
    cfg.decode_low_watermark = low_watermark;
    let policy = ArrowPolicy::new(cfg, n);
    let instances: Vec<SimInstance> = (0..n)
        .map(|i| {
            let mut inst = SimInstance::new(InstanceId(i), CostModel::h800_llama8b());
            if chunk_cap {
                inst.iter_time_budget = Some(0.8 * tpot_slo);
            }
            inst
        })
        .collect();
    Cluster::new(instances, Box::new(policy), SimConfig::default())
}

fn score(cl: Cluster, t: &Trace, ttft: f64, tpot: f64) -> SloReport {
    let res = cl.run(t);
    SloReport::from_records(&res.records, ttft, tpot, t.duration())
}

fn main() {
    let w = catalog::by_name("azure_code").unwrap();
    let trace = w.generate(1).clip_seconds(300.0);
    let rate = trace.rate() * 12.0;
    let t = trace.with_rate(rate);
    println!(
        "workload: azure_code clip @ {:.1} req/s, SLO ttft={}s tpot={}s\n",
        rate, w.ttft_slo, w.tpot_slo
    );

    println!("== Fig. 8 arms: scheduling strategy ==");
    let arms = [System::Arrow, System::MinimalLoad, System::RoundRobin];
    let reps = parallel_map(arms.to_vec(), default_workers(), |&sys| {
        let cl = build(sys, 8, &CostModel::h800_llama8b(), w.ttft_slo, w.tpot_slo, false);
        score(cl, &t, w.ttft_slo, w.tpot_slo)
    });
    for (sys, rep) in arms.iter().zip(&reps) {
        println!(
            "  {:<13} attainment={:.3} p90_ttft={:.2}s p90_tpot={:.4}s",
            sys.label(),
            rep.slo_attainment,
            rep.p90_ttft,
            rep.p90_tpot
        );
    }

    println!("\n== Arrow design-knob ablations (same workload) ==");
    let knobs: Vec<(&str, usize, f64, bool)> = vec![
        ("default (4P/4D, wm=0.5, chunk-cap on)", 4, 0.5, true),
        ("no chunk cap (mixed-iter interference)", 4, 0.5, false),
        ("no overload guard (wm=1.0)", 4, 1.0, true),
        ("prefill-heavy start (6P/2D)", 6, 0.5, true),
        ("decode-heavy start (2P/6D)", 2, 0.5, true),
    ];
    let reps = parallel_map(knobs.clone(), default_workers(), |&(_, p0, wm, cap)| {
        let cl = arrow_cluster_with(8, w.ttft_slo, w.tpot_slo, p0, wm, cap);
        score(cl, &t, w.ttft_slo, w.tpot_slo)
    });
    for ((name, ..), rep) in knobs.iter().zip(&reps) {
        println!(
            "  {:<40} attainment={:.3} p90_ttft={:.2}s p90_tpot={:.4}s",
            name, rep.slo_attainment, rep.p90_ttft, rep.p90_tpot
        );
    }
    println!("\nexpected: default >= every ablated variant; initial split matters");
    println!("little (elastic pools adapt), chunk-cap protects TPOT.");
}
