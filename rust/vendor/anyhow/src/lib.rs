//! Offline in-tree substitute for the `anyhow` crate (PR 3 seed-test
//! triage).
//!
//! The repo is a zero-external-dependency build (DESIGN: every substrate
//! — rand, proptest, serde, HTTP — is vendored or re-implemented), but
//! the seed's server/runtime layers were written against `anyhow`,
//! leaving the whole crate unbuildable offline. This shim implements the
//! small API subset those layers use — `Error`, `Result`, `anyhow!`,
//! `bail!`, and the `Context` extension trait — with the same `?`
//! ergonomics (any `std::error::Error` converts into [`Error`]).
//!
//! If a real dependency tree ever becomes available, deleting
//! `[dependencies.anyhow]`'s `path` key in ../../Cargo.toml swaps the
//! genuine crate back in with no source changes.

use std::fmt;

/// A boxed, context-chained error: a message plus the chain of contexts
/// wrapped around it (outermost first), rendered `ctx: ...: cause`.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error {
            chain: vec![m.to_string()],
        }
    }

    /// Wrap another layer of context around this error.
    pub fn context<C: fmt::Display>(mut self, c: C) -> Error {
        self.chain.insert(0, c.to_string());
        self
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // anyhow renders Debug as the display chain; error reporting at
        // the top of main uses {:?}.
        write!(f, "{self}")
    }
}

// `?` conversion from any std error. Mirrors anyhow: `Error` itself does
// NOT implement `std::error::Error`, which is what keeps this blanket
// impl coherent next to the reflexive `From<Error> for Error`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `anyhow!("...")` — format a new [`Error`].
#[macro_export]
macro_rules! anyhow {
    ($($t:tt)*) => { $crate::Error::msg(format!($($t)*)) }
}

/// `bail!("...")` — return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => { return Err($crate::anyhow!($($t)*)) }
}

/// Context-attachment extension for `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T>
    for std::result::Result<T, E>
{
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| Error::msg(e).context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read("/definitely/not/a/path/3f9a")?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_fail().unwrap_err();
        assert!(!e.to_string().is_empty());
    }

    #[test]
    fn context_chains_outermost_first() {
        let e: Result<()> = std::fs::read("/nope/3f9a")
            .map(|_| ())
            .with_context(|| format!("reading {}", "/nope/3f9a"));
        let msg = format!("{}", e.unwrap_err());
        assert!(msg.starts_with("reading /nope/3f9a: "), "{msg}");
    }

    #[test]
    fn bail_and_anyhow_format() {
        fn f(x: u32) -> Result<u32> {
            if x == 0 {
                bail!("zero is not allowed (got {x})");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(
            format!("{}", f(0).unwrap_err()),
            "zero is not allowed (got 0)"
        );
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing field").unwrap_err();
        assert_eq!(e.to_string(), "missing field");
    }
}
