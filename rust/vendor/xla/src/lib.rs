//! Offline stub of the `xla` (xla_extension / PJRT) bindings (PR 3
//! seed-test triage).
//!
//! The real-mode serving path (`arrow::runtime`, `arrow::server`) is
//! written against the PJRT bindings crate, which needs the native
//! `xla_extension` toolchain — unavailable in the offline build. This
//! stub reproduces the exact API surface `arrow::runtime` consumes so
//! the whole workspace **compiles and unit-tests everywhere**, while the
//! real-mode entry point fails fast at [`PjRtClient::cpu`] with a clear
//! message. The artifact-gated integration tests already skip when
//! `artifacts/` is missing, so `cargo test` is green without hardware.
//!
//! To run real mode, point the `xla` entry of ../../Cargo.toml at the
//! genuine bindings (same types, same methods) — no source changes in
//! `arrow` are needed.

use std::fmt;

/// Stub error: also what every method returns.
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable() -> Error {
    Error(
        "xla_extension is not linked in this build: the offline stub only \
         provides the API surface. Swap vendor/xla for the real PJRT \
         bindings to run real mode."
            .to_string(),
    )
}

pub struct PjRtClient;
pub struct PjRtBuffer;
pub struct PjRtLoadedExecutable;
pub struct HloModuleProto;
pub struct XlaComputation;
pub struct Literal;

impl PjRtClient {
    /// Real bindings: construct the CPU PJRT client. Stub: fail fast so
    /// `ModelRuntime::load` reports a clear startup error.
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(unavailable())
    }

    pub fn buffer_from_host_buffer<T: Copy>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer, Error> {
        Err(unavailable())
    }

    pub fn compile(&self, _c: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(unavailable())
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(unavailable())
    }
}

impl PjRtLoadedExecutable {
    pub fn execute_b<B>(&self, _args: &[B]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(unavailable())
    }
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
        Err(unavailable())
    }
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

impl Literal {
    pub fn to_tuple3(&self) -> Result<(Literal, Literal, Literal), Error> {
        Err(unavailable())
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        Err(unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_fails_fast_with_a_clear_message() {
        let e = PjRtClient::cpu().err().expect("stub must not pretend");
        assert!(format!("{e:?}").contains("xla_extension"), "{e}");
    }
}
