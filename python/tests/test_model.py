"""L2 correctness: model shapes, pallas/ref agreement, prefill/decode split.

The decisive test is ``test_split_generation_matches_ref``: greedy tokens
produced by (one prefill) + (N decode steps through the KV cache handoff)
must exactly equal tokens produced by repeated full-prefill generation.
That equivalence is what makes the disaggregated serving path correct.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import model as M
from compile.configs import TEST, TINY


@pytest.fixture(scope="module")
def params():
    return M.init_params(TEST, seed=0)


def _padded(prompt, s):
    toks = jnp.zeros((1, s), jnp.int32).at[0, : len(prompt)].set(
        jnp.asarray(prompt, jnp.int32)
    )
    return toks, jnp.int32(len(prompt))


# ----------------------------------------------------------------- shapes

def test_param_spec_matches_count():
    cfg = TEST
    total = sum(int(np.prod(sh)) for _, sh in M.param_spec(cfg))
    assert total == cfg.n_params


def test_param_spec_order_deterministic():
    a = [n for n, _ in M.param_spec(TEST)]
    b = [n for n, _ in M.param_spec(TEST)]
    assert a == b
    assert a[0] == "embed" and a[-1] == "unembed"


def test_init_params_deterministic():
    p1 = M.init_params(TEST, seed=3)
    p2 = M.init_params(TEST, seed=3)
    for k in p1:
        assert_allclose(np.asarray(p1[k]), np.asarray(p2[k]))


def test_init_params_seed_sensitivity():
    p1 = M.init_params(TEST, seed=1)
    p2 = M.init_params(TEST, seed=2)
    assert not np.allclose(np.asarray(p1["embed"]), np.asarray(p2["embed"]))


def test_prefill_shapes(params):
    cfg = TEST
    s = cfg.prefill_buckets[0]
    toks, vlen = _padded([1, 2, 3], s)
    first, k, v = M.prefill_step(params, toks, vlen, cfg)
    assert first.shape == (1,) and first.dtype == jnp.int32
    assert k.shape == (cfg.n_layers, s, cfg.n_heads, cfg.head_dim)
    assert v.shape == k.shape


def test_decode_shapes(params):
    cfg = TEST
    b, t, l = cfg.decode_batch, cfg.max_seq_len, cfg.n_layers
    kv = jnp.zeros((l, b, t, cfg.n_heads, cfg.head_dim), jnp.float32)
    tok = jnp.zeros((b,), jnp.int32)
    clen = jnp.zeros((b,), jnp.int32)
    nxt, k, v = M.decode_step(params, tok, kv, kv, clen, cfg)
    assert nxt.shape == (b,) and nxt.dtype == jnp.int32
    assert k.shape == kv.shape and v.shape == kv.shape


# ------------------------------------------------- pallas == ref (at L2)

def test_prefill_pallas_matches_ref(params):
    cfg = TEST
    s = cfg.prefill_buckets[1]
    toks, vlen = _padded([5, 9, 2, 7, 11, 3], s)
    f1, k1, v1 = M.prefill_step(params, toks, vlen, cfg, use_pallas=True)
    f2, k2, v2 = M.prefill_step(params, toks, vlen, cfg, use_pallas=False)
    assert int(f1[0]) == int(f2[0])
    assert_allclose(np.asarray(k1), np.asarray(k2), rtol=1e-4, atol=1e-4)
    assert_allclose(np.asarray(v1), np.asarray(v2), rtol=1e-4, atol=1e-4)


def test_decode_pallas_matches_ref(params):
    cfg = TEST
    b, t, l = cfg.decode_batch, cfg.max_seq_len, cfg.n_layers
    rng = np.random.default_rng(0)
    kv = jnp.asarray(rng.standard_normal((l, b, t, cfg.n_heads, cfg.head_dim)),
                     jnp.float32) * 0.3
    tok = jnp.asarray(rng.integers(0, cfg.vocab_size, b), jnp.int32)
    clen = jnp.asarray([3, 7], jnp.int32)[:b]
    n1, k1, v1 = M.decode_step(params, tok, kv, kv, clen, cfg, use_pallas=True)
    n2, k2, v2 = M.decode_step(params, tok, kv, kv, clen, cfg, use_pallas=False)
    assert np.array_equal(np.asarray(n1), np.asarray(n2))
    assert_allclose(np.asarray(k1), np.asarray(k2), rtol=1e-4, atol=1e-4)


# ----------------------------------------------- split-generation oracle

def test_split_generation_matches_ref(params):
    """prefill -> KV handoff -> decode iterations == full-prefill greedy."""
    cfg = TEST
    prompt = [3, 7, 11, 2, 9, 1, 4, 8]
    n_new = 5
    expected = M.generate_ref(params, jnp.asarray(prompt, jnp.int32), n_new, cfg)

    s = cfg.prefill_buckets[1]
    toks, vlen = _padded(prompt, s)
    first, kpre, vpre = M.prefill_step(params, toks, vlen, cfg)

    b, t, l = cfg.decode_batch, cfg.max_seq_len, cfg.n_layers
    kc = jnp.zeros((l, b, t, cfg.n_heads, cfg.head_dim), jnp.float32)
    vc = jnp.zeros_like(kc)
    kc = kc.at[:, 0, : len(prompt)].set(kpre[:, : len(prompt)])
    vc = vc.at[:, 0, : len(prompt)].set(vpre[:, : len(prompt)])
    clen = jnp.zeros((b,), jnp.int32).at[0].set(len(prompt))
    tok = jnp.zeros((b,), jnp.int32).at[0].set(int(first[0]))

    got = [int(first[0])]
    for _ in range(n_new - 1):
        nxt, kc, vc = M.decode_step(params, tok, kc, vc, clen, cfg)
        clen = clen + 1
        tok = nxt
        got.append(int(nxt[0]))
    assert got == expected


def test_split_generation_two_slots_independent(params):
    """Two concurrent sequences in one decode batch generate the same
    tokens as each alone — continuous batching must not cross-talk."""
    cfg = TEST
    prompts = [[3, 7, 11, 2], [9, 1, 4, 8, 5, 6]]
    n_new = 4
    solo = [
        M.generate_ref(params, jnp.asarray(p, jnp.int32), n_new, cfg)
        for p in prompts
    ]

    s = cfg.prefill_buckets[1]
    b, t, l = cfg.decode_batch, cfg.max_seq_len, cfg.n_layers
    kc = jnp.zeros((l, b, t, cfg.n_heads, cfg.head_dim), jnp.float32)
    vc = jnp.zeros_like(kc)
    clen = jnp.zeros((b,), jnp.int32)
    tok = jnp.zeros((b,), jnp.int32)
    for slot, p in enumerate(prompts):
        toks, vlen = _padded(p, s)
        first, kpre, vpre = M.prefill_step(params, toks, vlen, cfg)
        kc = kc.at[:, slot, : len(p)].set(kpre[:, : len(p)])
        vc = vc.at[:, slot, : len(p)].set(vpre[:, : len(p)])
        clen = clen.at[slot].set(len(p))
        tok = tok.at[slot].set(int(first[0]))

    got = [[int(tok[0])], [int(tok[1])]]
    for _ in range(n_new - 1):
        nxt, kc, vc = M.decode_step(params, tok, kc, vc, clen, cfg)
        clen = clen + 1
        tok = nxt
        got[0].append(int(nxt[0]))
        got[1].append(int(nxt[1]))
    assert got[0] == solo[0]
    assert got[1] == solo[1]


def test_prefill_bucket_invariance(params):
    """The same prompt in different buckets yields identical first token
    and KV prefix — bucket padding must be inert."""
    cfg = TEST
    prompt = [2, 4, 6]
    outs = []
    for s in cfg.prefill_buckets:
        toks, vlen = _padded(prompt, s)
        first, k, v = M.prefill_step(params, toks, vlen, cfg)
        outs.append((int(first[0]), np.asarray(k[:, : len(prompt)])))
    assert outs[0][0] == outs[1][0]
    assert_allclose(outs[0][1], outs[1][1], rtol=1e-4, atol=1e-4)


def test_idle_slots_do_not_disturb_active(params):
    """Garbage in idle slots (cache_len=0) must not change active slots."""
    cfg = TEST
    b, t, l = cfg.decode_batch, cfg.max_seq_len, cfg.n_layers
    rng = np.random.default_rng(1)
    kv = jnp.asarray(
        rng.standard_normal((l, b, t, cfg.n_heads, cfg.head_dim)), jnp.float32
    ) * 0.2
    tok = jnp.asarray([7] + [0] * (b - 1), jnp.int32)
    clen = jnp.asarray([4] + [0] * (b - 1), jnp.int32)
    n1, _, _ = M.decode_step(params, tok, kv, kv, clen, cfg)
    # scramble idle slots
    kv2 = kv.at[:, 1:].set(jnp.asarray(
        rng.standard_normal((l, b - 1, t, cfg.n_heads, cfg.head_dim)),
        jnp.float32))
    tok2 = tok.at[1:].set(13)
    n2, _, _ = M.decode_step(params, tok2, kv2, kv2, clen, cfg)
    assert int(n1[0]) == int(n2[0])


def test_tiny_config_consistency():
    cfg = TINY
    assert cfg.max_seq_len >= max(cfg.prefill_buckets)
    assert cfg.d_model == cfg.n_heads * cfg.head_dim
    assert cfg.n_params > 1_000_000
