"""AOT path tests: HLO text emission, weights blob layout, artifact index.

Uses the TEST config (tiny shapes) so lowering stays fast. The emitted HLO
must be plain text starting with ``HloModule`` — the only format the rust
side's xla_extension 0.5.1 parses (64-bit-proto-id issue; see aot.py).
"""

import json
import os

import numpy as np
import pytest

from compile import aot
from compile import model as M
from compile.configs import TEST


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    aot.build(TEST, str(out), seed=0)
    return str(out)


def test_hlo_text_format(built):
    for s in TEST.prefill_buckets:
        path = os.path.join(built, f"prefill_s{s}.hlo.txt")
        with open(path) as f:
            text = f.read()
        assert text.startswith("HloModule"), "must be HLO text, not proto"
        assert "ENTRY" in text
    with open(os.path.join(built, f"decode_b{TEST.decode_batch}.hlo.txt")) as f:
        assert f.read().startswith("HloModule")


def test_hlo_entry_parameter_count(built):
    """Entry computation takes |params| + step operands."""
    n_params = len(M.param_spec(TEST))
    with open(os.path.join(built, f"prefill_s{TEST.prefill_buckets[0]}.hlo.txt")) as f:
        text = f.read()
    entry = text[text.index("ENTRY"):]
    body = entry[: entry.index("ROOT")]
    n_args = body.count("parameter(")
    assert n_args == n_params + 2  # tokens, valid_len
    with open(os.path.join(built, f"decode_b{TEST.decode_batch}.hlo.txt")) as f:
        text = f.read()
    entry = text[text.index("ENTRY"):]
    body = entry[: entry.index("ROOT")]
    assert body.count("parameter(") == n_params + 4  # tok, k, v, clen


def test_weights_blob_layout(built):
    with open(os.path.join(built, "weights_manifest.json")) as f:
        man = json.load(f)
    assert man["dtype"] == "f32le"
    spec = M.param_spec(TEST)
    assert [t["name"] for t in man["tensors"]] == [n for n, _ in spec]
    # Offsets are contiguous and sizes match shapes.
    off = 0
    for t, (_, shape) in zip(man["tensors"], spec):
        assert t["offset_bytes"] == off
        assert t["size_bytes"] == int(np.prod(shape)) * 4
        off += t["size_bytes"]
    assert man["total_bytes"] == off
    assert os.path.getsize(os.path.join(built, "weights.bin")) == off


def test_weights_blob_values_roundtrip(built):
    """weights.bin content == init_params(seed) in canonical order."""
    params = M.init_params(TEST, seed=0)
    with open(os.path.join(built, "weights_manifest.json")) as f:
        man = json.load(f)
    blob = np.fromfile(os.path.join(built, "weights.bin"), dtype="<f4")
    for t in man["tensors"]:
        n = t["size_bytes"] // 4
        got = blob[t["offset_bytes"] // 4 :][:n].reshape(t["shape"])
        np.testing.assert_allclose(got, np.asarray(params[t["name"]]),
                                   rtol=0, atol=0)


def test_model_config_index(built):
    with open(os.path.join(built, "model_config.json")) as f:
        cfg = json.load(f)
    assert cfg["name"] == TEST.name
    assert set(cfg["artifacts"]["prefill"]) == {str(s) for s in TEST.prefill_buckets}
    assert cfg["artifacts"]["decode"] == f"decode_b{TEST.decode_batch}.hlo.txt"
    assert cfg["kv_bytes_per_token"] == TEST.kv_bytes_per_token
    assert cfg["n_params"] == TEST.n_params


def test_lowered_prefill_deterministic():
    """Same config → byte-identical HLO text (hermetic AOT)."""
    a = aot.lower_prefill(TEST, TEST.prefill_buckets[0])
    b = aot.lower_prefill(TEST, TEST.prefill_buckets[0])
    assert a == b
