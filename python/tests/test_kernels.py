"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes/dtypes/valid-lengths; assert_allclose against
``kernels/ref.py``. This is the CORE correctness signal for the kernels
that end up inside every AOT artifact.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import (
    decode_attention,
    flash_prefill_attention,
    ref,
    rmsnorm,
)
from compile.kernels.attention import (
    vmem_estimate_decode,
    vmem_estimate_prefill,
)

RTOL, ATOL = 2e-5, 2e-5


def _rand(rng, shape, dtype=np.float32):
    x = rng.standard_normal(shape).astype(dtype)
    return jnp.asarray(x)


# ---------------------------------------------------------------- prefill

@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    s_blocks=st.integers(1, 4),
    block=st.sampled_from([8, 16, 32]),
    h=st.sampled_from([1, 2, 4]),
    d=st.sampled_from([8, 16, 32]),
    vfrac=st.floats(0.1, 1.0),
)
def test_prefill_matches_ref(seed, s_blocks, block, h, d, vfrac):
    s = s_blocks * block
    rng = np.random.default_rng(seed)
    q, k, v = (_rand(rng, (s, h, d)) for _ in range(3))
    vlen = max(1, int(round(s * vfrac)))
    out = flash_prefill_attention(q, k, v, vlen, block_q=block, block_k=block)
    exp = ref.causal_attention_ref(q, k, v, vlen)
    # Only valid positions are meaningful.
    assert_allclose(np.asarray(out[:vlen]), np.asarray(exp[:vlen]),
                    rtol=RTOL, atol=ATOL)


def test_prefill_full_length_no_mask():
    rng = np.random.default_rng(7)
    s, h, d = 64, 4, 32
    q, k, v = (_rand(rng, (s, h, d)) for _ in range(3))
    out = flash_prefill_attention(q, k, v, s, block_q=32, block_k=16)
    exp = ref.causal_attention_ref(q, k, v, s)
    assert_allclose(np.asarray(out), np.asarray(exp), rtol=RTOL, atol=ATOL)


def test_prefill_vlen_one_attends_only_first():
    """With valid_len=1 the first query attends only to itself => out = v[0]."""
    rng = np.random.default_rng(3)
    s, h, d = 16, 2, 8
    q, k, v = (_rand(rng, (s, h, d)) for _ in range(3))
    out = flash_prefill_attention(q, k, v, 1, block_q=8, block_k=8)
    assert_allclose(np.asarray(out[0]), np.asarray(v[0]), rtol=RTOL, atol=ATOL)


def test_prefill_block_mismatch_raises():
    rng = np.random.default_rng(0)
    q = _rand(rng, (24, 2, 8))
    with pytest.raises(ValueError):
        flash_prefill_attention(q, q, q, 24, block_q=16, block_k=16)


def test_prefill_rejects_nonsquare_padding_leak():
    """Tokens past valid_len must not influence valid outputs."""
    rng = np.random.default_rng(11)
    s, h, d = 32, 2, 16
    q, k, v = (_rand(rng, (s, h, d)) for _ in range(3))
    vlen = 10
    out1 = flash_prefill_attention(q, k, v, vlen, block_q=16, block_k=16)
    # Scramble the padding region of k/v; valid outputs must be unchanged.
    k2 = k.at[vlen:].set(999.0)
    v2 = v.at[vlen:].set(-999.0)
    out2 = flash_prefill_attention(q, k2, v2, vlen, block_q=16, block_k=16)
    assert_allclose(np.asarray(out1[:vlen]), np.asarray(out2[:vlen]),
                    rtol=RTOL, atol=ATOL)


# ----------------------------------------------------------------- decode

@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    b=st.integers(1, 4),
    t_blocks=st.integers(1, 4),
    block=st.sampled_from([8, 16]),
    h=st.sampled_from([1, 2, 4]),
    d=st.sampled_from([8, 16, 32]),
)
def test_decode_matches_ref(seed, b, t_blocks, block, h, d):
    t = t_blocks * block
    rng = np.random.default_rng(seed)
    q = _rand(rng, (b, h, d))
    kc = _rand(rng, (b, t, h, d))
    vc = _rand(rng, (b, t, h, d))
    clen = jnp.asarray(rng.integers(1, t + 1, size=b), jnp.int32)
    out = decode_attention(q, kc, vc, clen, block_t=block)
    exp = ref.decode_attention_ref(q, kc, vc, clen)
    assert_allclose(np.asarray(out), np.asarray(exp), rtol=RTOL, atol=ATOL)


def test_decode_len_one_returns_v0():
    rng = np.random.default_rng(5)
    b, t, h, d = 2, 16, 2, 8
    q = _rand(rng, (b, h, d))
    kc = _rand(rng, (b, t, h, d))
    vc = _rand(rng, (b, t, h, d))
    clen = jnp.asarray([1, 1], jnp.int32)
    out = decode_attention(q, kc, vc, clen, block_t=8)
    assert_allclose(np.asarray(out), np.asarray(vc[:, 0]), rtol=RTOL, atol=ATOL)


def test_decode_padding_isolation():
    """Cache entries >= cache_len must not influence the output."""
    rng = np.random.default_rng(9)
    b, t, h, d = 2, 32, 2, 8
    q = _rand(rng, (b, h, d))
    kc = _rand(rng, (b, t, h, d))
    vc = _rand(rng, (b, t, h, d))
    clen = jnp.asarray([5, 17], jnp.int32)
    out1 = decode_attention(q, kc, vc, clen, block_t=16)
    kc2 = kc.at[0, 5:].set(1e4).at[1, 17:].set(1e4)
    vc2 = vc.at[0, 5:].set(-1e4).at[1, 17:].set(-1e4)
    out2 = decode_attention(q, kc2, vc2, clen, block_t=16)
    assert_allclose(np.asarray(out1), np.asarray(out2), rtol=RTOL, atol=ATOL)


def test_decode_heterogeneous_lengths_independent_slots():
    """Each slot's output depends only on its own cache/query."""
    rng = np.random.default_rng(13)
    b, t, h, d = 3, 16, 2, 8
    q = _rand(rng, (b, h, d))
    kc = _rand(rng, (b, t, h, d))
    vc = _rand(rng, (b, t, h, d))
    clen = jnp.asarray([4, 9, 16], jnp.int32)
    full = decode_attention(q, kc, vc, clen, block_t=8)
    for i in range(b):
        solo = decode_attention(q[i:i+1], kc[i:i+1], vc[i:i+1], clen[i:i+1],
                                block_t=8)
        assert_allclose(np.asarray(full[i]), np.asarray(solo[0]),
                        rtol=RTOL, atol=ATOL)


# ---------------------------------------------------------------- rmsnorm

@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    n_blocks=st.integers(1, 4),
    block=st.sampled_from([4, 8, 16]),
    d=st.sampled_from([8, 32, 128]),
)
def test_rmsnorm_matches_ref(seed, n_blocks, block, d):
    n = n_blocks * block
    rng = np.random.default_rng(seed)
    x = _rand(rng, (n, d))
    sc = _rand(rng, (d,))
    out = rmsnorm(x, sc, block_rows=block)
    exp = ref.rmsnorm_ref(x, sc)
    assert_allclose(np.asarray(out), np.asarray(exp), rtol=RTOL, atol=ATOL)


def test_rmsnorm_unit_scale_unit_rows():
    """Rows with rms 1 and scale 1 pass through unchanged."""
    x = jnp.ones((8, 16), jnp.float32)
    out = rmsnorm(x, jnp.ones((16,), jnp.float32), block_rows=8)
    assert_allclose(np.asarray(out), np.ones((8, 16), np.float32),
                    rtol=1e-4, atol=1e-5)


# -------------------------------------------------------- vmem estimates

def test_vmem_estimates_monotone_and_bounded():
    small = vmem_estimate_prefill(128, 32, 64, 64)
    big = vmem_estimate_prefill(256, 32, 128, 128)
    assert 0 < small < big
    assert vmem_estimate_prefill(256, 32, 128, 128) < 16 * 2**20  # fits VMEM
    assert vmem_estimate_decode(288, 8, 32, 128) < 16 * 2**20
    assert vmem_estimate_decode(128, 8, 32, 64) < vmem_estimate_decode(
        256, 8, 32, 64
    )
