"""Model configurations for the AOT-compiled serving model.

The serving demo uses a tiny Llama-style decoder so that the full
HTTP -> Arrow scheduler -> PJRT execute path runs in real time on CPU.
The paper's Llama-3.1-8B latencies are reproduced by the *calibrated cost
model* on the rust side (see DESIGN.md §3); this model's job is to prove the
three-layer stack composes, and to provide real per-iteration latencies for
calibrating the simulator's quadratic-prefill / linear-decode fits.
"""

from dataclasses import dataclass, asdict


@dataclass(frozen=True)
class ModelConfig:
    """Static hyper-parameters of the Llama-style decoder."""

    name: str = "tiny-llama"
    vocab_size: int = 2048
    d_model: int = 256
    n_layers: int = 4
    n_heads: int = 8
    head_dim: int = 32
    ffn_dim: int = 704          # SwiGLU inner dim, ~8/3 * d_model rounded
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    # Serving shapes (static: one HLO artifact per bucket).
    prefill_buckets: tuple = (32, 128, 256)
    decode_batch: int = 4
    max_seq_len: int = 384      # KV capacity per slot (max bucket + headroom)

    @property
    def kv_bytes_per_token(self) -> int:
        """f32 K+V bytes for one token across all layers."""
        return self.n_layers * 2 * self.n_heads * self.head_dim * 4

    @property
    def n_params(self) -> int:
        d, h, hd, f = self.d_model, self.n_heads, self.head_dim, self.ffn_dim
        per_layer = (
            4 * d * (h * hd)   # wq wk wv wo
            + 3 * d * f        # w_gate w_up w_down
            + 2 * d            # two rmsnorm scales
        )
        return self.vocab_size * d * 2 + self.n_layers * per_layer + d

    def to_dict(self) -> dict:
        d = asdict(self)
        d["prefill_buckets"] = list(self.prefill_buckets)
        d["kv_bytes_per_token"] = self.kv_bytes_per_token
        d["n_params"] = self.n_params
        return d


TINY = ModelConfig()

# Smaller config used only by fast unit tests.
TEST = ModelConfig(
    name="test-llama",
    vocab_size=64,
    d_model=32,
    n_layers=2,
    n_heads=2,
    head_dim=16,
    ffn_dim=48,
    prefill_buckets=(8, 16),
    decode_batch=2,
    max_seq_len=24,
)
