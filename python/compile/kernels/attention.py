"""Pallas attention kernels (L1) — the serving hot-spot.

Two kernels, mirroring the two phases the paper disaggregates:

* ``flash_prefill_attention`` — causal flash attention with online softmax,
  tiled over (head, q-block) grid steps, K/V streamed block-by-block.
  This is the quadratic-in-S prefill workload (paper §3.1/§4.2).
* ``decode_attention`` — single-token attention of a batch of queries
  against padded per-sequence KV caches; linear in total cached tokens
  (paper §4.3).

TPU adaptation of the paper's GPU setting (DESIGN.md §4): tiles are sized
for VMEM staging via BlockSpec instead of CUDA shared-memory blocks; the
inner q@k^T / p@v contractions are MXU-shaped matmuls. Kernels run with
``interpret=True`` so the AOT HLO contains plain ops the CPU PJRT client
executes; real-TPU perf is estimated from the block geometry (DESIGN.md §9).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes. 128 matches the MXU systolic dimension; for the tiny
# demo model (S <= 288) blocks clamp to the sequence length.
DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128

_NEG_INF = -1e30


def _prefill_kernel(q_ref, k_ref, v_ref, vlen_ref, o_ref, *, block_k: int, s: int):
    """One grid step: all K/V blocks folded into one q-block of one head.

    Online-softmax accumulators (m, l, acc) live in registers/VMEM for the
    whole step; K/V are visited in ``block_k`` chunks.
    """
    h = pl.program_id(0)
    qi = pl.program_id(1)
    del h  # head is selected by the BlockSpec index_map
    q = q_ref[...].astype(jnp.float32)  # [block_q, d]
    block_q, d = q.shape
    scale = 1.0 / jnp.sqrt(jnp.float32(d))
    q = q * scale
    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, 1), 0)
    vlen = vlen_ref[0]

    n_kb = s // block_k

    def body(kb, carry):
        m_prev, l_prev, acc = carry
        k = jax.lax.dynamic_slice_in_dim(k_ref[...], kb * block_k, block_k).astype(
            jnp.float32
        )  # [block_k, d]
        v = jax.lax.dynamic_slice_in_dim(v_ref[...], kb * block_k, block_k).astype(
            jnp.float32
        )
        logits = q @ k.T  # [block_q, block_k] — MXU matmul
        k_pos = kb * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (1, block_k), 1
        )
        mask = (k_pos <= q_pos) & (k_pos < vlen)
        logits = jnp.where(mask, logits, _NEG_INF)
        m_cur = jnp.max(logits, axis=-1, keepdims=True)  # [block_q, 1]
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(logits - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * alpha + p @ v  # MXU matmul
        return m_new, l_new, acc

    m0 = jnp.full((block_q, 1), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q, 1), jnp.float32)
    acc0 = jnp.zeros((block_q, d), jnp.float32)
    _, l, acc = jax.lax.fori_loop(0, n_kb, body, (m0, l0, acc0))
    # Padding queries attend to nothing valid when vlen==0; avoid 0/0.
    o_ref[...] = (acc / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


def flash_prefill_attention(
    q: jnp.ndarray,  # [S, H, D]
    k: jnp.ndarray,  # [S, H, D]
    v: jnp.ndarray,  # [S, H, D]
    valid_len,       # scalar int32 (static or traced)
    *,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = True,
) -> jnp.ndarray:
    """Causal flash attention over one padded prefill sequence."""
    s, h, d = q.shape
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    if s % block_q or s % block_k:
        raise ValueError(f"S={s} must be divisible by block sizes {block_q},{block_k}")
    vlen = jnp.asarray(valid_len, jnp.int32).reshape((1,))

    # Layout: put head first so each grid step sees a contiguous [S, D] slab.
    qt = q.transpose(1, 0, 2)  # [H, S, D]
    kt = k.transpose(1, 0, 2)
    vt = v.transpose(1, 0, 2)

    grid = (h, s // block_q)
    out = pl.pallas_call(
        functools.partial(_prefill_kernel, block_k=block_k, s=s),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, block_q, d), lambda hh, qi: (hh, qi, 0)),
            pl.BlockSpec((None, s, d), lambda hh, qi: (hh, 0, 0)),
            pl.BlockSpec((None, s, d), lambda hh, qi: (hh, 0, 0)),
            pl.BlockSpec((1,), lambda hh, qi: (0,)),
        ],
        out_specs=pl.BlockSpec((None, block_q, d), lambda hh, qi: (hh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((h, s, d), q.dtype),
        interpret=interpret,
    )(qt, kt, vt, vlen)
    return out.transpose(1, 0, 2)  # back to [S, H, D]


def _decode_kernel(q_ref, k_ref, v_ref, len_ref, o_ref, *, block_t: int, t: int):
    """One grid step = one batch element: attend one query to its KV cache."""
    q = q_ref[...].astype(jnp.float32)  # [H, D]
    h, d = q.shape
    scale = 1.0 / jnp.sqrt(jnp.float32(d))
    q = q * scale
    clen = len_ref[...]  # scalar: BlockSpec (None,) collapses the batch dim

    n_tb = t // block_t

    def body(tb, carry):
        m_prev, l_prev, acc = carry
        kk = jax.lax.dynamic_slice_in_dim(k_ref[...], tb * block_t, block_t).astype(
            jnp.float32
        )  # [block_t, H, D]
        vv = jax.lax.dynamic_slice_in_dim(v_ref[...], tb * block_t, block_t).astype(
            jnp.float32
        )
        logits = jnp.einsum("hd,thd->ht", q, kk)  # [H, block_t]
        t_pos = tb * block_t + jax.lax.broadcasted_iota(jnp.int32, (1, block_t), 1)
        logits = jnp.where(t_pos < clen, logits, _NEG_INF)
        m_cur = jnp.max(logits, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(logits - m_new)  # [H, block_t]
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * alpha + jnp.einsum("ht,thd->hd", p, vv)
        return m_new, l_new, acc

    m0 = jnp.full((h, 1), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((h, 1), jnp.float32)
    acc0 = jnp.zeros((h, d), jnp.float32)
    _, l, acc = jax.lax.fori_loop(0, n_tb, body, (m0, l0, acc0))
    o_ref[...] = (acc / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


def decode_attention(
    q: jnp.ndarray,          # [B, H, D]
    k_cache: jnp.ndarray,    # [B, T, H, D]
    v_cache: jnp.ndarray,    # [B, T, H, D]
    cache_len: jnp.ndarray,  # [B] int32
    *,
    block_t: int = DEFAULT_BLOCK_K,
    interpret: bool = True,
) -> jnp.ndarray:
    """Batched single-token decode attention against padded KV caches."""
    b, h, d = q.shape
    t = k_cache.shape[1]
    block_t = min(block_t, t)
    if t % block_t:
        raise ValueError(f"T={t} must be divisible by block_t={block_t}")
    clen = cache_len.astype(jnp.int32)

    out = pl.pallas_call(
        functools.partial(_decode_kernel, block_t=block_t, t=t),
        grid=(b,),
        in_specs=[
            pl.BlockSpec((None, h, d), lambda bb: (bb, 0, 0)),
            pl.BlockSpec((None, t, h, d), lambda bb: (bb, 0, 0, 0)),
            pl.BlockSpec((None, t, h, d), lambda bb: (bb, 0, 0, 0)),
            pl.BlockSpec((None,), lambda bb: (bb,)),
        ],
        out_specs=pl.BlockSpec((None, h, d), lambda bb: (bb, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, d), q.dtype),
        interpret=interpret,
    )(q, k_cache, v_cache, clen)
    return out


def vmem_estimate_prefill(s: int, d: int, block_q: int, block_k: int) -> int:
    """Bytes of VMEM one prefill grid step touches (f32). Used by DESIGN §9."""
    q_tile = block_q * d * 4
    kv_resident = 2 * s * d * 4  # full K and V slabs for the head
    accs = block_q * (d + 2) * 4
    out = block_q * d * 4
    return q_tile + kv_resident + accs + out


def vmem_estimate_decode(t: int, h: int, d: int, block_t: int) -> int:
    """Bytes of VMEM one decode grid step touches (f32)."""
    q_tile = h * d * 4
    kv_resident = 2 * t * h * d * 4
    accs = h * (d + 2) * 4
    return q_tile + kv_resident + accs + h * d * 4
