"""Pure-jnp reference oracles for the Pallas kernels.

Every Pallas kernel in this package has an exact (up to float tolerance)
counterpart here; pytest asserts allclose between the two across
hypothesis-generated shapes. These references are also what the L2 model
falls back to when ``use_pallas=False`` (useful for debugging lowering
issues independently of kernel bugs).
"""

import jax.numpy as jnp


def rmsnorm_ref(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    """RMSNorm over the last axis: x * scale / rms(x)."""
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * jnp.reciprocal(jnp.sqrt(var + eps)) * scale).astype(x.dtype)


def causal_attention_ref(
    q: jnp.ndarray,  # [S, H, D]
    k: jnp.ndarray,  # [S, H, D]
    v: jnp.ndarray,  # [S, H, D]
    valid_len=None,
) -> jnp.ndarray:
    """Causal self-attention for a single (prefill) sequence.

    Positions >= valid_len are padding: they may attend (their output is
    garbage and discarded) but are never attended *to* by valid positions.
    """
    s = q.shape[0]
    scale = 1.0 / jnp.sqrt(jnp.float32(q.shape[-1]))
    # [H, S, S]
    logits = jnp.einsum("qhd,khd->hqk", q.astype(jnp.float32), k.astype(jnp.float32))
    logits = logits * scale
    pos = jnp.arange(s)
    mask = pos[None, :] <= pos[:, None]  # causal [q, k]
    if valid_len is not None:
        mask = mask & (pos[None, :] < valid_len)
    logits = jnp.where(mask[None, :, :], logits, -1e30)
    probs = jnp.exp(logits - jnp.max(logits, axis=-1, keepdims=True))
    probs = probs / jnp.sum(probs, axis=-1, keepdims=True)
    out = jnp.einsum("hqk,khd->qhd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def decode_attention_ref(
    q: jnp.ndarray,          # [B, H, D] single new query per sequence
    k_cache: jnp.ndarray,    # [B, T, H, D]
    v_cache: jnp.ndarray,    # [B, T, H, D]
    cache_len: jnp.ndarray,  # [B] int32: number of valid cache entries
) -> jnp.ndarray:
    """Single-token decode attention against a (padded) KV cache.

    Entry ``t`` of the cache is valid iff ``t < cache_len[b]``. The new
    token's own K/V must already be written at position ``cache_len[b]-1``
    by the caller (i.e. cache_len counts it).
    """
    t = k_cache.shape[1]
    scale = 1.0 / jnp.sqrt(jnp.float32(q.shape[-1]))
    logits = jnp.einsum(
        "bhd,bthd->bht", q.astype(jnp.float32), k_cache.astype(jnp.float32)
    )
    logits = logits * scale
    valid = jnp.arange(t)[None, :] < cache_len[:, None]  # [B, T]
    logits = jnp.where(valid[:, None, :], logits, -1e30)
    probs = jnp.exp(logits - jnp.max(logits, axis=-1, keepdims=True))
    probs = probs / jnp.sum(probs, axis=-1, keepdims=True)
    out = jnp.einsum("bht,bthd->bhd", probs, v_cache.astype(jnp.float32))
    return out.astype(q.dtype)


def swiglu_ref(x, w_gate, w_up, w_down):
    """SwiGLU FFN: down( silu(x@gate) * (x@up) )."""
    xf = x.astype(jnp.float32)
    g = xf @ w_gate.astype(jnp.float32)
    u = xf @ w_up.astype(jnp.float32)
    out = (g * jnp.reciprocal(1.0 + jnp.exp(-g)) * u) @ w_down.astype(jnp.float32)
    return out.astype(x.dtype)
