"""L1 Pallas kernels and their pure-jnp reference oracles."""

from . import ref  # noqa: F401
from .attention import decode_attention, flash_prefill_attention  # noqa: F401
from .rmsnorm import rmsnorm  # noqa: F401
