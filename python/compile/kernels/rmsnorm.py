"""Pallas RMSNorm kernel — the small fused pre-attention/pre-FFN norm.

Grid is one step per row-block; the reduction over the feature axis happens
entirely in VMEM. Validated against ``ref.rmsnorm_ref`` by pytest.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, scale_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)  # [block_rows, d]
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    inv = jnp.reciprocal(jnp.sqrt(var + eps))
    o_ref[...] = (x * inv * scale_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm(
    x: jnp.ndarray,      # [N, D]
    scale: jnp.ndarray,  # [D]
    eps: float = 1e-5,
    *,
    block_rows: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    """RMSNorm over the last axis of a 2-D array."""
    n, d = x.shape
    block_rows = min(block_rows, n)
    if n % block_rows:
        raise ValueError(f"N={n} must be divisible by block_rows={block_rows}")
    return pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(n // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), x.dtype),
        interpret=interpret,
    )(x, scale)
