"""L2: Llama-style decoder model (JAX), calling the L1 Pallas kernels.

Two entry points are AOT-lowered per model (see ``aot.py``):

* ``prefill_step(params, tokens[1,S], valid_len)`` →
  ``(first_token[1] i32, k_cache [L,S,H,Dh], v_cache [L,S,H,Dh])``
  One HLO artifact per prefill bucket S; quadratic cost in S.

* ``decode_step(params, tokens[B], k_cache [L,B,T,H,Dh], v_cache alike,
  cache_len[B])`` → ``(next_tokens[B] i32, k_cache', v_cache')``
  ``cache_len[b]`` is the number of tokens already cached for slot ``b``
  (0 = inactive slot). The new token's K/V is written at position
  ``cache_len[b]``; attention then covers ``cache_len[b]+1`` entries.
  Linear cost in total cached tokens — exactly the scaling the paper's
  decode-load analysis (§4.3) relies on.

Weights are explicit parameters (not baked constants) so the HLO stays
small; the rust runtime feeds them from ``artifacts/weights.bin`` following
``weights_manifest.json`` (see ``aot.py``).

Greedy argmax sampling happens inside the graph so the coordinator moves
only token ids, never logits.
"""

import math

import jax
import jax.numpy as jnp

from .configs import ModelConfig
from .kernels import ref
from .kernels.attention import decode_attention, flash_prefill_attention
from .kernels.rmsnorm import rmsnorm


# --------------------------------------------------------------------------
# Parameters
# --------------------------------------------------------------------------

def param_spec(cfg: ModelConfig) -> list[tuple[str, tuple[int, ...]]]:
    """Ordered (name, shape) list — the canonical flattening order used by
    both ``aot.py`` (weights.bin writer) and the rust runtime (reader)."""
    d, h, hd, f = cfg.d_model, cfg.n_heads, cfg.head_dim, cfg.ffn_dim
    spec: list[tuple[str, tuple[int, ...]]] = [("embed", (cfg.vocab_size, d))]
    for i in range(cfg.n_layers):
        p = f"layer{i}."
        spec += [
            (p + "attn_norm", (d,)),
            (p + "wq", (d, h * hd)),
            (p + "wk", (d, h * hd)),
            (p + "wv", (d, h * hd)),
            (p + "wo", (h * hd, d)),
            (p + "ffn_norm", (d,)),
            (p + "w_gate", (d, f)),
            (p + "w_up", (d, f)),
            (p + "w_down", (f, d)),
        ]
    spec += [("final_norm", (d,)), ("unembed", (d, cfg.vocab_size))]
    return spec


def init_params(cfg: ModelConfig, seed: int = 0) -> dict[str, jnp.ndarray]:
    """Seeded random init (scaled normal). No pretrained weights are
    available offline; the serving demo needs realistic *compute*, not
    realistic *text* (DESIGN.md §3)."""
    key = jax.random.PRNGKey(seed)
    params = {}
    for name, shape in param_spec(cfg):
        key, sub = jax.random.split(key)
        if name.endswith("norm"):
            params[name] = jnp.ones(shape, jnp.float32)
        else:
            std = 1.0 / math.sqrt(shape[0])
            params[name] = (
                jax.random.normal(sub, shape, jnp.float32) * std
            )
    return params


# --------------------------------------------------------------------------
# Building blocks
# --------------------------------------------------------------------------

def _rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Rotary embedding. x: [..., H, Dh]; positions broadcastable to x[...,0,0]."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None, None].astype(jnp.float32) * freqs  # [..., 1, half]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1).astype(
        x.dtype
    )


def _norm(x2d, scale, cfg: ModelConfig, use_pallas: bool):
    if use_pallas and x2d.shape[0] % min(128, x2d.shape[0]) == 0:
        return rmsnorm(x2d, scale, cfg.norm_eps, block_rows=min(128, x2d.shape[0]))
    return ref.rmsnorm_ref(x2d, scale, cfg.norm_eps)


def _ffn(x2d, p, prefix):
    return ref.swiglu_ref(
        x2d, p[prefix + "w_gate"], p[prefix + "w_up"], p[prefix + "w_down"]
    )


# --------------------------------------------------------------------------
# Prefill
# --------------------------------------------------------------------------

def prefill_step(
    params: dict,
    tokens: jnp.ndarray,  # [1, S] int32, padded with zeros beyond valid_len
    valid_len: jnp.ndarray,  # scalar int32
    cfg: ModelConfig,
    *,
    use_pallas: bool = True,
):
    """Full-sequence prefill. Returns the greedily sampled first output
    token and the per-layer K/V for handoff to a decode instance."""
    s = tokens.shape[1]
    h, hd = cfg.n_heads, cfg.head_dim
    x = params["embed"][tokens[0]]  # [S, D]
    positions = jnp.arange(s)
    ks, vs = [], []
    for i in range(cfg.n_layers):
        p = f"layer{i}."
        xn = _norm(x, params[p + "attn_norm"], cfg, use_pallas)
        q = (xn @ params[p + "wq"]).reshape(s, h, hd)
        k = (xn @ params[p + "wk"]).reshape(s, h, hd)
        v = (xn @ params[p + "wv"]).reshape(s, h, hd)
        q = _rope(q, positions, cfg.rope_theta)
        k = _rope(k, positions, cfg.rope_theta)
        if use_pallas:
            attn = flash_prefill_attention(
                q, k, v, valid_len, block_q=min(128, s), block_k=min(128, s)
            )
        else:
            attn = ref.causal_attention_ref(q, k, v, valid_len)
        x = x + attn.reshape(s, h * hd) @ params[p + "wo"]
        xn = _norm(x, params[p + "ffn_norm"], cfg, use_pallas)
        x = x + _ffn(xn, params, p)
        ks.append(k)
        vs.append(v)
    xn = _norm(x, params["final_norm"], cfg, use_pallas)
    logits = xn @ params["unembed"]  # [S, V]
    # The first output token comes from the *last valid* position.
    last = logits[valid_len - 1]
    first_token = jnp.argmax(last, axis=-1).astype(jnp.int32).reshape(1)
    k_cache = jnp.stack(ks)  # [L, S, H, Dh]
    v_cache = jnp.stack(vs)
    return first_token, k_cache, v_cache


# --------------------------------------------------------------------------
# Decode
# --------------------------------------------------------------------------

def decode_step(
    params: dict,
    tokens: jnp.ndarray,     # [B] int32 — last emitted token per slot
    k_cache: jnp.ndarray,    # [L, B, T, H, Dh]
    v_cache: jnp.ndarray,    # [L, B, T, H, Dh]
    cache_len: jnp.ndarray,  # [B] int32 — tokens already cached (0 = idle)
    cfg: ModelConfig,
    *,
    use_pallas: bool = True,
    return_rows: bool = False,
):
    """One continuous-batching decode iteration over B slots.

    With ``return_rows=True`` (the AOT serving artifact), the updated
    caches are NOT returned; instead the per-layer new K/V rows
    ``[L, B, H, Dh]`` are, and the host scatters them at position
    ``cache_len[b]`` — shrinking the per-step device→host transfer from
    O(L·B·T·H·Dh) to O(L·B·H·Dh) (see EXPERIMENTS.md §Perf-L2).
    """
    b = tokens.shape[0]
    h, hd = cfg.n_heads, cfg.head_dim
    x = params["embed"][tokens]  # [B, D]
    pos = cache_len  # new token's position index
    new_len = cache_len + 1
    k_out, v_out = k_cache, v_cache
    k_rows, v_rows = [], []
    for i in range(cfg.n_layers):
        p = f"layer{i}."
        xn = _norm(x, params[p + "attn_norm"], cfg, use_pallas)
        q = (xn @ params[p + "wq"]).reshape(b, h, hd)
        k = (xn @ params[p + "wk"]).reshape(b, h, hd)
        v = (xn @ params[p + "wv"]).reshape(b, h, hd)
        q = _rope(q, pos, cfg.rope_theta)
        k = _rope(k, pos, cfg.rope_theta)
        # Scatter the new K/V at position cache_len[b] for every slot.
        bidx = jnp.arange(b)
        k_out = k_out.at[i, bidx, pos].set(k)
        v_out = v_out.at[i, bidx, pos].set(v)
        k_rows.append(k)
        v_rows.append(v)
        if use_pallas:
            t_cap = k_cache.shape[2]
            block_t = next(bt for bt in (128, 64, 32, 16, 8, 4, 2, 1)
                           if t_cap % bt == 0)
            attn = decode_attention(q, k_out[i], v_out[i], new_len,
                                    block_t=block_t)
        else:
            attn = ref.decode_attention_ref(q, k_out[i], v_out[i], new_len)
        x = x + attn.reshape(b, h * hd) @ params[p + "wo"]
        xn = _norm(x, params[p + "ffn_norm"], cfg, use_pallas)
        x = x + _ffn(xn, params, p)
    xn = _norm(x, params["final_norm"], cfg, use_pallas)
    logits = xn @ params["unembed"]  # [B, V]
    next_tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    if return_rows:
        return next_tokens, jnp.stack(k_rows), jnp.stack(v_rows)
    return next_tokens, k_out, v_out


# --------------------------------------------------------------------------
# Reference full generation (tests only)
# --------------------------------------------------------------------------

def generate_ref(params, prompt: jnp.ndarray, n_new: int, cfg: ModelConfig):
    """Greedy generation via repeated full prefill — O(n^3), tests only.
    The prefill/decode split must produce exactly this token sequence."""
    toks = list(int(t) for t in prompt)
    out = []
    for _ in range(n_new):
        s = len(toks)
        # pad to next bucket-free length (any length works for the ref path)
        tok_arr = jnp.asarray([toks], jnp.int32)
        first, _, _ = prefill_step(
            params, tok_arr, jnp.int32(s), cfg, use_pallas=False
        )
        nxt = int(first[0])
        out.append(nxt)
        toks.append(nxt)
    return out
