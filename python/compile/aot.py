"""AOT compile path: lower the L2 model to HLO *text* artifacts.

Run once by ``make artifacts``; python never appears on the request path.

Interchange format is HLO text, not ``.serialize()``: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids that the rust side's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Emitted into ``artifacts/``:

  model_config.json        — hyper-params + serving shapes + artifact index
  weights.bin              — all parameters, little-endian f32, in the
                             canonical ``param_spec`` order
  weights_manifest.json    — name/shape/offset of each tensor in weights.bin
  prefill_s{S}.hlo.txt     — one per prefill bucket S
  decode_b{B}.hlo.txt      — the batched decode step

Parameter convention for the HLO entry computations: the model weights come
first (in ``param_spec`` order), then the step-specific operands. Outputs
are a tuple (lowered with return_tuple=True; rust unwraps with to_tupleN).
"""

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .configs import TINY, TEST, ModelConfig
from . import model as M

CONFIGS = {"tiny": TINY, "test": TEST}


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (the only format the rust
    side's XLA 0.5.1 parses; see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _prefill_fn(cfg: ModelConfig, param_names, *args):
    n = len(param_names)
    params = dict(zip(param_names, args[:n]))
    tokens, valid_len = args[n], args[n + 1]
    first, k, v = M.prefill_step(params, tokens, valid_len, cfg, use_pallas=True)
    return first, k, v


def _decode_fn(cfg: ModelConfig, param_names, *args):
    n = len(param_names)
    params = dict(zip(param_names, args[:n]))
    tokens, k_cache, v_cache, cache_len = args[n : n + 4]
    # return_rows: the artifact outputs only the per-layer new K/V rows
    # [L, B, H, Dh]; the rust host scatters them (EXPERIMENTS.md §Perf-L2).
    nxt, k_rows, v_rows = M.decode_step(
        params, tokens, k_cache, v_cache, cache_len, cfg,
        use_pallas=True, return_rows=True,
    )
    return nxt, k_rows, v_rows


def lower_prefill(cfg: ModelConfig, s: int) -> str:
    spec = M.param_spec(cfg)
    names = [n for n, _ in spec]
    shapes = [jax.ShapeDtypeStruct(sh, jnp.float32) for _, sh in spec]
    tok = jax.ShapeDtypeStruct((1, s), jnp.int32)
    vlen = jax.ShapeDtypeStruct((), jnp.int32)
    fn = functools.partial(_prefill_fn, cfg, names)
    lowered = jax.jit(fn).lower(*shapes, tok, vlen)
    return to_hlo_text(lowered)


def lower_decode(cfg: ModelConfig) -> str:
    spec = M.param_spec(cfg)
    names = [n for n, _ in spec]
    shapes = [jax.ShapeDtypeStruct(sh, jnp.float32) for _, sh in spec]
    b, t, l = cfg.decode_batch, cfg.max_seq_len, cfg.n_layers
    tok = jax.ShapeDtypeStruct((b,), jnp.int32)
    kv = jax.ShapeDtypeStruct((l, b, t, cfg.n_heads, cfg.head_dim), jnp.float32)
    clen = jax.ShapeDtypeStruct((b,), jnp.int32)
    fn = functools.partial(_decode_fn, cfg, names)
    lowered = jax.jit(fn).lower(*shapes, tok, kv, kv, clen)
    return to_hlo_text(lowered)


def write_weights(cfg: ModelConfig, out_dir: str, seed: int) -> dict:
    params = M.init_params(cfg, seed)
    manifest = []
    offset = 0
    blob_path = os.path.join(out_dir, "weights.bin")
    with open(blob_path, "wb") as f:
        for name, shape in M.param_spec(cfg):
            arr = np.asarray(params[name], dtype="<f4")
            assert tuple(arr.shape) == tuple(shape), name
            f.write(arr.tobytes())
            manifest.append(
                {
                    "name": name,
                    "shape": list(shape),
                    "offset_bytes": offset,
                    "size_bytes": arr.nbytes,
                }
            )
            offset += arr.nbytes
    with open(os.path.join(out_dir, "weights_manifest.json"), "w") as f:
        json.dump({"dtype": "f32le", "total_bytes": offset, "tensors": manifest}, f,
                  indent=1)
    return params


def build(cfg: ModelConfig, out_dir: str, seed: int = 0) -> None:
    os.makedirs(out_dir, exist_ok=True)
    write_weights(cfg, out_dir, seed)

    artifacts = {"prefill": {}, "decode": None}
    for s in cfg.prefill_buckets:
        name = f"prefill_s{s}.hlo.txt"
        text = lower_prefill(cfg, s)
        with open(os.path.join(out_dir, name), "w") as f:
            f.write(text)
        artifacts["prefill"][str(s)] = name
        print(f"  {name}: {len(text)} chars")
    name = f"decode_b{cfg.decode_batch}.hlo.txt"
    text = lower_decode(cfg)
    with open(os.path.join(out_dir, name), "w") as f:
        f.write(text)
    artifacts["decode"] = name
    print(f"  {name}: {len(text)} chars")

    config = cfg.to_dict()
    config["artifacts"] = artifacts
    config["seed"] = seed
    with open(os.path.join(out_dir, "model_config.json"), "w") as f:
        json.dump(config, f, indent=1)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument("--config", default="tiny", choices=sorted(CONFIGS))
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    cfg = CONFIGS[args.config]
    print(f"AOT-lowering model '{cfg.name}' ({cfg.n_params/1e6:.2f}M params) "
          f"-> {args.out}")
    build(cfg, args.out, args.seed)
    print("done")


if __name__ == "__main__":
    main()
