"""Build-time-only package: L1 Pallas kernels + L2 JAX model + AOT lowering.

Never imported at serving time — `make artifacts` runs once and the rust
binary is self-contained afterwards.
"""
