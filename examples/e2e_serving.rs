//! End-to-end driver (DESIGN.md §7 real mode): load the AOT-compiled
//! model into two stateless PJRT engines, serve a batch of concurrent
//! requests through the full HTTP → coordinator → engine path, verify
//! output determinism across the cross-engine KV handoff, and report
//! latency/throughput. Recorded in EXPERIMENTS.md §E2E.
//!
//! Since PR 2 the coordinator on this path runs the *actual*
//! `ArrowPolicy` (elastic pools + Alg. 1–4) through the shared `sched`
//! layer — the `/metrics` scrape at the end shows the live pool sizes
//! `[P, D, P→D, D→P]` and flip count coming from the policy's own
//! bookkeeping, not a server-side reimplementation.
//!
//! Run after `make artifacts` with:
//!   `cargo run --release --example e2e_serving`

use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

use arrow::json::Json;
use arrow::util::rng::Rng;
use arrow::util::stats;

const PORT: u16 = 18233;
const N_REQUESTS: usize = 24;
const CONCURRENCY: usize = 6;

fn http_post(addr: &str, path: &str, body: &str) -> Result<String, String> {
    let mut s = TcpStream::connect(addr).map_err(|e| e.to_string())?;
    s.set_read_timeout(Some(Duration::from_secs(180))).ok();
    let req = format!(
        "POST {path} HTTP/1.1\r\nHost: localhost\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    );
    s.write_all(req.as_bytes()).map_err(|e| e.to_string())?;
    let mut out = String::new();
    s.read_to_string(&mut out).map_err(|e| e.to_string())?;
    out.split_once("\r\n\r\n")
        .map(|x| x.1.to_string())
        .ok_or_else(|| "no body".into())
}

fn http_get(addr: &str, path: &str) -> Result<String, String> {
    let mut s = TcpStream::connect(addr).map_err(|e| e.to_string())?;
    s.set_read_timeout(Some(Duration::from_secs(10))).ok();
    s.write_all(format!("GET {path} HTTP/1.1\r\nHost: x\r\n\r\n").as_bytes())
        .map_err(|e| e.to_string())?;
    let mut out = String::new();
    s.read_to_string(&mut out).map_err(|e| e.to_string())?;
    out.split_once("\r\n\r\n")
        .map(|x| x.1.to_string())
        .ok_or_else(|| "no body".into())
}

fn main() {
    let addr = format!("127.0.0.1:{PORT}");
    // Start the real server in-process (2 stateless engines).
    std::thread::spawn(|| {
        arrow::server::serve(arrow::server::ServeConfig {
            artifacts_dir: "artifacts".into(),
            port: PORT,
            instances: 2,
            ttft_slo: 2.0,
            tpot_slo: 0.5,
            admin_token: None, // membership endpoints not exercised here
        })
        .expect("server failed — run `make artifacts` first");
    });

    // Wait for readiness (engine compilation takes a few seconds).
    let t0 = Instant::now();
    loop {
        if http_get(&addr, "/healthz").map(|b| b == "ok").unwrap_or(false) {
            break;
        }
        if t0.elapsed() > Duration::from_secs(120) {
            eprintln!("server did not become ready; did you run `make artifacts`?");
            std::process::exit(1);
        }
        std::thread::sleep(Duration::from_millis(250));
    }
    println!("server ready in {:.1}s", t0.elapsed().as_secs_f64());

    // Fire N_REQUESTS concurrent completions (varied prompts/lengths).
    let mut rng = Rng::new(7);
    let jobs: Vec<(Vec<i64>, usize)> = (0..N_REQUESTS)
        .map(|_| {
            let len = rng.int_range(4, 48) as usize;
            let prompt: Vec<i64> = (0..len).map(|_| rng.int_range(1, 2047)).collect();
            let max_tokens = rng.int_range(4, 24) as usize;
            (prompt, max_tokens)
        })
        .collect();

    let bench_t0 = Instant::now();
    let results = arrow::util::threads::parallel_map(jobs.clone(), CONCURRENCY, |(prompt, max_tokens)| {
        let body = Json::obj(vec![
            (
                "tokens",
                Json::Arr(prompt.iter().map(|&t| Json::Num(t as f64)).collect()),
            ),
            ("max_tokens", Json::Num(*max_tokens as f64)),
        ]);
        let t0 = Instant::now();
        let resp = http_post(&format!("127.0.0.1:{PORT}"), "/v1/completions", &body.encode());
        (resp, t0.elapsed().as_secs_f64())
    });
    let wall = bench_t0.elapsed().as_secs_f64();

    // Validate + aggregate.
    let mut latencies = Vec::new();
    let mut tokens_out = 0usize;
    let mut failures = 0usize;
    let mut first_result: Option<Vec<i64>> = None;
    for ((_, max_tokens), (resp, lat)) in jobs.iter().zip(&results) {
        match resp.as_ref().ok().and_then(|b| Json::parse(b).ok()) {
            Some(v) if v.get("tokens").as_arr().is_some() => {
                let toks = v.get("tokens").as_arr().unwrap();
                assert_eq!(toks.len(), *max_tokens, "wrong output length");
                tokens_out += toks.len();
                latencies.push(*lat);
                if first_result.is_none() {
                    first_result =
                        Some(toks.iter().filter_map(|x| x.as_i64()).collect());
                }
            }
            _ => failures += 1,
        }
    }
    assert_eq!(failures, 0, "all requests must succeed");

    // Determinism across the KV-handoff path: replay request 0 and
    // compare token-for-token.
    let (p0, m0) = &jobs[0];
    let body = Json::obj(vec![
        (
            "tokens",
            Json::Arr(p0.iter().map(|&t| Json::Num(t as f64)).collect()),
        ),
        ("max_tokens", Json::Num(*m0 as f64)),
    ]);
    let replay = http_post(&addr, "/v1/completions", &body.encode()).unwrap();
    let replay_tokens: Vec<i64> = Json::parse(&replay)
        .unwrap()
        .get("tokens")
        .as_arr()
        .unwrap()
        .iter()
        .filter_map(|x| x.as_i64())
        .collect();
    assert_eq!(
        Some(replay_tokens),
        first_result,
        "greedy decoding must be deterministic"
    );

    latencies.sort_by(|a, b| a.total_cmp(b));
    println!("\n=== E2E serving report ===");
    println!("requests        : {N_REQUESTS} (concurrency {CONCURRENCY}), 0 failures");
    println!("output tokens   : {tokens_out}");
    println!("wall time       : {wall:.2}s");
    println!("throughput      : {:.1} tokens/s, {:.2} req/s", tokens_out as f64 / wall, N_REQUESTS as f64 / wall);
    println!("latency p50     : {:.3}s", stats::percentile_sorted(&latencies, 50.0));
    println!("latency p90     : {:.3}s", stats::percentile_sorted(&latencies, 90.0));
    println!("latency max     : {:.3}s", latencies.last().unwrap());
    println!("determinism     : replay of request 0 matched token-for-token");
    let metrics = http_get(&addr, "/metrics").unwrap();
    println!("server /metrics : {metrics}");

    // The server runs the shared Arrow policy: pool sizes must partition
    // the engine set and the latency percentiles must be populated.
    let m = Json::parse(&metrics).unwrap();
    let pools: Vec<u64> = m
        .get("pools")
        .as_arr()
        .expect("pools [P, D, P>D, D>P] in /metrics")
        .iter()
        .filter_map(|x| x.as_u64())
        .collect();
    assert_eq!(pools.iter().sum::<u64>(), 2, "pools partition 2 engines");
    assert!(m.get("p99_ttft_s").as_f64().is_some(), "p99 TTFT reported");
    println!(
        "arrow pools     : [P,D,P>D,D>P]={pools:?} flips={}",
        m.get("flips").as_f64().unwrap_or(0.0)
    );
    println!("\nE2E OK — full stack (HTTP → Arrow policy → PJRT engines → KV handoff) verified.");
    std::process::exit(0);
}
