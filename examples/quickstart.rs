//! Quickstart: simulate Arrow vs the static baselines on a bursty
//! workload and print the headline comparison in under a second.
//!
//! Run with: `cargo run --release --example quickstart`

use arrow::costmodel::CostModel;
use arrow::metrics::SloReport;
use arrow::scenarios::{build, System};
use arrow::trace::catalog;

fn main() {
    // The Azure Code trace: long prompts, tiny outputs, heavy bursts —
    // the workload where adaptive PD-ratio scheduling matters most.
    let w = catalog::by_name("azure_code").expect("catalog");
    let trace = w.generate(42).clip_seconds(300.0);
    println!(
        "workload: {} ({} requests over {:.0}s, TTFT SLO {}s, TPOT SLO {}s)",
        w.name(),
        trace.len(),
        trace.duration(),
        w.ttft_slo,
        w.tpot_slo
    );

    // Push the cluster to 12x the recorded arrival rate.
    let t = trace.with_rate(trace.rate() * 12.0);
    println!("replaying at {:.1} req/s on 8 simulated H800 GPUs\n", t.rate());

    println!(
        "{:<14} {:>10} {:>10} {:>10} {:>7}",
        "system", "SLO att.", "p90 TTFT", "p90 TPOT", "flips"
    );
    for sys in System::all() {
        let cluster = build(sys, 8, &CostModel::h800_llama8b(), w.ttft_slo, w.tpot_slo, false);
        let res = cluster.run(&t);
        let rep = SloReport::from_records(&res.records, w.ttft_slo, w.tpot_slo, t.duration());
        println!(
            "{:<14} {:>9.1}% {:>9.2}s {:>9.3}s {:>7}",
            sys.label(),
            rep.slo_attainment * 100.0,
            rep.p90_ttft,
            rep.p90_tpot,
            res.total_flips
        );
    }
    println!("\nArrow's elastic pools absorb the bursts that overwhelm the");
    println!("static 4P/4D splits; see `arrow figures fig7` for full sweeps.");
}
