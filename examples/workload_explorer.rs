//! Workload explorer: regenerate the four production-trace surrogates,
//! print their §3.1 statistics next to the paper's published numbers, and
//! render quick ASCII load timelines (Fig. 1's shape at terminal scale).
//!
//! Run with: `cargo run --release --example workload_explorer`
//!
//! Large-cluster mode (PR 4): `--instances N` skips the trace tour and
//! instead drives `scenarios::large_cluster(N)` through a deep-queue
//! burst — the O(1)-placement scale path, demoable without the bench
//! harness:
//!
//! ```text
//! cargo run --release --example workload_explorer -- --instances 64
//! ```

use arrow::trace::catalog;

fn sparkline(values: &[f64]) -> String {
    const BARS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
    let max = values.iter().cloned().fold(f64::MIN, f64::max).max(1.0);
    values
        .iter()
        .map(|&v| BARS[((v / max) * 7.0).round().clamp(0.0, 7.0) as usize])
        .collect()
}

/// `--instances N`: run a deep-queue burst through an N-instance Arrow
/// cluster and report how the scheduler held up at scale.
fn large_cluster_tour(n: usize) {
    use arrow::costmodel::CostModel;
    use arrow::metrics::SloReport;
    use arrow::scenarios;
    use std::time::Instant;

    let (ttft_slo, tpot_slo) = (5.0, 0.1);
    let per_instance = 8;
    let trace = scenarios::deep_queue_burst(n, per_instance, 10.0, 1);
    println!(
        "large-cluster mode: {n} instances, {} requests arriving in a 10s burst \
         (~{per_instance} queued behind every instance)\n",
        trace.len()
    );
    let cl = scenarios::large_cluster(n, &CostModel::h800_llama8b(), ttft_slo, tpot_slo);
    let t0 = Instant::now();
    let res = cl.run(&trace);
    let wall = t0.elapsed().as_secs_f64();
    let rep = SloReport::from_records(&res.records, ttft_slo, tpot_slo, trace.duration());

    println!(
        "drained in {:.2}s simulated time ({wall:.2}s wall, {:.0} events/s)",
        res.sim_time,
        res.events_processed as f64 / wall.max(1e-9)
    );
    println!(
        "finished {}/{} requests, {} pool flips, {} iterations",
        rep.n_finished,
        rep.n_requests,
        res.total_flips,
        res.total_iterations
    );
    println!(
        "TTFT p50/p90/p99: {:.2}/{:.2}/{:.2}s   TPOT p50/p99: {:.0}/{:.0}ms",
        rep.p50_ttft,
        rep.p90_ttft,
        rep.p99_ttft,
        rep.p50_tpot * 1e3,
        rep.p99_tpot * 1e3
    );
    println!(
        "SLO attainment: {:.1}% (TTFT {:.1}%, TPOT {:.1}%)",
        rep.slo_attainment * 100.0,
        rep.ttft_attainment * 100.0,
        rep.tpot_attainment * 100.0
    );
    println!(
        "\nplacement stayed O(1) per candidate throughout — sweep the cluster size \
         with `cargo bench --bench scale` (emits BENCH_scale.json)."
    );
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    if let Some(pos) = args.iter().position(|a| a == "--instances") {
        let n = args
            .get(pos + 1)
            .and_then(|v| v.parse::<usize>().ok())
            .filter(|&n| n >= 8)
            .unwrap_or_else(|| {
                eprintln!("usage: workload_explorer --instances N   (N >= 8, e.g. 64 or 256)");
                std::process::exit(2);
            });
        large_cluster_tour(n);
        return;
    }

    println!("paper-published statistics vs synthetic surrogates (seed 1):\n");
    println!(
        "{:<15} {:>7} {:>9} {:>9} {:>7} {:>7}  paper says",
        "trace", "#req", "med_in", "med_out", "io_r", "min_cv"
    );
    let published = [
        ("azure_code", "r=0.95, cv=0.80, 8819 reqs"),
        ("azure_conv", "r=0.29, 19366 reqs"),
        ("burstgpt", "cv=1.11, 6009 reqs"),
        ("mooncake_conv", "cv=0.16, long-context, 1756 reqs"),
    ];
    for (name, note) in published {
        let w = catalog::by_name(name).unwrap();
        let t = w.generate(1);
        let s = t.stats();
        println!(
            "{:<15} {:>7} {:>9.0} {:>9.0} {:>7.2} {:>7.2}  {}",
            name, s.n, s.median_input, s.median_output, s.io_correlation, s.minute_input_cv, note
        );
    }

    println!("\nper-minute input-token load (Fig. 1 at terminal scale):\n");
    for w in catalog::table1() {
        let t = w.generate(1);
        let series: Vec<f64> = t
            .per_minute_load()
            .iter()
            .map(|m| m.input_tokens as f64)
            .collect();
        println!("{:<15} {}", w.name(), sparkline(&series));
    }

    println!("\nrate rescaling (§7.1 evaluation workflow):");
    let t = catalog::by_name("azure_code").unwrap().generate(1);
    for mult in [1.0, 2.0, 8.0] {
        let r = t.with_rate(t.rate() * mult);
        println!(
            "  x{:<4} -> {:.2} req/s over {:.0}s ({} requests, lengths unchanged)",
            mult,
            r.rate(),
            r.duration(),
            r.len()
        );
    }
    println!("\nexport all traces as JSONL with: `arrow traces --out results/traces`");
}
