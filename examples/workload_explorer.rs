//! Workload explorer: regenerate the four production-trace surrogates,
//! print their §3.1 statistics next to the paper's published numbers, and
//! render quick ASCII load timelines (Fig. 1's shape at terminal scale).
//!
//! Run with: `cargo run --release --example workload_explorer`

use arrow::trace::catalog;

fn sparkline(values: &[f64]) -> String {
    const BARS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
    let max = values.iter().cloned().fold(f64::MIN, f64::max).max(1.0);
    values
        .iter()
        .map(|&v| BARS[((v / max) * 7.0).round().clamp(0.0, 7.0) as usize])
        .collect()
}

fn main() {
    println!("paper-published statistics vs synthetic surrogates (seed 1):\n");
    println!(
        "{:<15} {:>7} {:>9} {:>9} {:>7} {:>7}  paper says",
        "trace", "#req", "med_in", "med_out", "io_r", "min_cv"
    );
    let published = [
        ("azure_code", "r=0.95, cv=0.80, 8819 reqs"),
        ("azure_conv", "r=0.29, 19366 reqs"),
        ("burstgpt", "cv=1.11, 6009 reqs"),
        ("mooncake_conv", "cv=0.16, long-context, 1756 reqs"),
    ];
    for (name, note) in published {
        let w = catalog::by_name(name).unwrap();
        let t = w.generate(1);
        let s = t.stats();
        println!(
            "{:<15} {:>7} {:>9.0} {:>9.0} {:>7.2} {:>7.2}  {}",
            name, s.n, s.median_input, s.median_output, s.io_correlation, s.minute_input_cv, note
        );
    }

    println!("\nper-minute input-token load (Fig. 1 at terminal scale):\n");
    for w in catalog::table1() {
        let t = w.generate(1);
        let series: Vec<f64> = t
            .per_minute_load()
            .iter()
            .map(|m| m.input_tokens as f64)
            .collect();
        println!("{:<15} {}", w.name(), sparkline(&series));
    }

    println!("\nrate rescaling (§7.1 evaluation workflow):");
    let t = catalog::by_name("azure_code").unwrap().generate(1);
    for mult in [1.0, 2.0, 8.0] {
        let r = t.with_rate(t.rate() * mult);
        println!(
            "  x{:<4} -> {:.2} req/s over {:.0}s ({} requests, lengths unchanged)",
            mult,
            r.rate(),
            r.duration(),
            r.len()
        );
    }
    println!("\nexport all traces as JSONL with: `arrow traces --out results/traces`");
}
