//! Burst adaptation demo (paper Insight 5 + §5.5): watch Arrow's elastic
//! pools reshape in real time as a synthetic traffic spike arrives —
//! and, since PR 3, watch *elastic membership* absorb the same spike by
//! scaling the instance set itself.
//!
//! Act 1 prints a per-second timeline of pool sizes [P, D, P→D, D→P] and
//! the prefill/decode load, showing the D→P flips when the burst hits and
//! the P→D flips as decode load catches up — the temporal-misalignment
//! opportunity Fig. 4 motivates.
//!
//! Act 2 replays the workload on a smaller fixed cluster vs the same
//! cluster with spare instances joining right as the spike lands
//! (`scenarios::spike_scale_out`): the joiners land in whichever pool
//! Alg. 1's SLO test picks and the tail TTFT collapses.
//!
//! The `ArrowPolicy` making these moves is the substrate-agnostic one
//! from `arrow::sched` (PR 2): the simulator feeds it `SimView`
//! snapshots here, and `arrow serve` feeds the identical object
//! `ServerView` snapshots in production — the same pool timeline this
//! demo prints is what the live server's `/metrics` `pools` field
//! exposes, and the same joins are what `POST /admin/scale-out` does.
//!
//! Run with: `cargo run --release --example burst_adaptation`

use arrow::costmodel::CostModel;
use arrow::metrics::SloReport;
use arrow::request::Request;
use arrow::scenarios::{build, spike_scale_out, System};
use arrow::trace::Trace;
use arrow::util::rng::Rng;

fn main() {
    // Hand-built workload: 20s of calm traffic, a 10-second prefill-heavy
    // burst, then calm again.
    let mut rng = Rng::new(11);
    let mut reqs = Vec::new();
    let mut id = 0u64;
    let mut push = |t: f64, inp: u32, out: u32, id: &mut u64| {
        reqs.push(Request::new(*id, t, inp, out));
        *id += 1;
    };
    for s in 0..120 {
        let t = s as f64;
        // Baseline: ~2 req/s of modest requests.
        for _ in 0..2 {
            push(
                t + rng.f64(),
                rng.int_range(500, 3_000) as u32,
                rng.int_range(50, 200) as u32,
                &mut id,
            );
        }
        // Burst: seconds 20..30 add 25 long-prompt requests per second.
        if (20..30).contains(&s) {
            for _ in 0..25 {
                push(
                    t + rng.f64(),
                    rng.int_range(8_000, 40_000) as u32,
                    rng.int_range(20, 120) as u32,
                    &mut id,
                );
            }
        }
    }
    let trace = Trace::new("burst-demo", reqs);
    println!(
        "workload: {} requests over {:.0}s with a prefill burst at t=20..30s\n",
        trace.len(),
        trace.duration()
    );

    let (ttft_slo, tpot_slo) = (3.0, 0.1);
    let cluster = build(
        System::Arrow,
        8,
        &CostModel::h800_llama8b(),
        ttft_slo,
        tpot_slo,
        true, // record timeline
    );
    let res = cluster.run(&trace);

    println!(
        "{:>5} {:>14} {:>9} {:>9}   pool sizes",
        "t(s)", "[P,D,P>D,D>P]", "prefillQ", "decodeR"
    );
    for snap in res.timeline.iter().step_by(2) {
        let pools = snap.pools.unwrap_or([0; 4]);
        let prefill: usize = snap.per_instance.iter().map(|x| x.0).sum();
        let decode: usize = snap.per_instance.iter().map(|x| x.1).sum();
        let bar: String = "P".repeat(pools[0])
            + &"D".repeat(pools[1])
            + &"d".repeat(pools[2])  // P→D draining
            + &"p".repeat(pools[3]); // D→P draining
        println!(
            "{:>5.0} [{},{},{},{}]{:>8} {:>9} {:>9}   {}",
            snap.time, pools[0], pools[1], pools[2], pools[3], "", prefill, decode, bar
        );
        if snap.time > 75.0 {
            break;
        }
    }

    let rep = SloReport::from_records(&res.records, ttft_slo, tpot_slo, trace.duration());
    println!(
        "\nresult: attainment={:.1}% p90 TTFT={:.2}s p90 TPOT={:.3}s flips={}",
        rep.slo_attainment * 100.0,
        rep.p90_ttft,
        rep.p90_tpot,
        res.total_flips
    );
    assert!(res.total_flips > 0, "the burst must trigger pool flips");
    println!("note the Prefill pool growing right at the burst and shrinking after.");

    // ---- Act 2: elastic membership absorbs the same spike (PR 3) ----
    // A 4-GPU cluster takes the identical workload twice: once with fixed
    // membership, once with 4 spare instances joining at t=20s, the
    // moment the burst lands (what an autoscaler reacting to queue depth
    // would do via POST /admin/scale-out on the live server).
    println!("\n== elastic membership vs the same burst (PR 3) ==");
    let base = CostModel::h800_llama8b();
    let fixed = build(System::Arrow, 4, &base, ttft_slo, tpot_slo, false).run(&trace);
    let elastic = spike_scale_out(4, 4, &base, ttft_slo, tpot_slo, 20.0).run(&trace);
    let rep_fixed = SloReport::from_records(&fixed.records, ttft_slo, tpot_slo, trace.duration());
    let rep_elastic =
        SloReport::from_records(&elastic.records, ttft_slo, tpot_slo, trace.duration());
    println!(
        "{:<22} {:>10} {:>10} {:>10}",
        "membership", "SLO att.", "p99 TTFT", "p90 TPOT"
    );
    for (name, r) in [("fixed (4 GPUs)", &rep_fixed), ("scale-out (4+4 @20s)", &rep_elastic)] {
        println!(
            "{:<22} {:>9.1}% {:>9.2}s {:>9.3}s",
            name,
            r.slo_attainment * 100.0,
            r.p99_ttft,
            r.p90_tpot
        );
    }
    let spares_used = elastic
        .records
        .iter()
        .any(|r| r.prefill_instance.is_some_and(|i| i.0 >= 4)
            || r.decode_instance.is_some_and(|i| i.0 >= 4));
    assert!(spares_used, "joining instances must absorb part of the spike");
    assert!(
        rep_elastic.p99_ttft <= rep_fixed.p99_ttft,
        "scale-out must not worsen tail TTFT"
    );
    println!("\nthe joiners take the queue the fixed cluster can only backlog.");
}
